//! The full-SSD discrete-event model.
//!
//! Composition (Fig. 1/Fig. 2): a host link (single-stream SATA by
//! default, NVMe-style multi-queue via `[host]`) feeds requests through
//! the (optional) DRAM cache and the FTL into per-channel pluggable way
//! schedulers (`[qos]`, [`crate::controller::sched`]); each channel's bus
//! (NAND_IF + ECC) is a serialized resource; each way's chip imposes
//! t_R / t_PROG / t_BERS array busy times.
//!
//! ## Multi-tenant traffic
//!
//! A trace may tag each request with a (stream, priority class) pair
//! ([`SsdSim::set_streams`]). Streams map to submission queues on the
//! multi-queue link (closed-loop admission honors a per-queue depth and
//! the configured queue arbitration), page jobs inherit their request's
//! class — background GC/WL/migration traffic carries the explicit
//! lowest class — and completion latency/throughput is additionally
//! accounted per stream for the QoS reports (`ddrnand sweep-qos`).
//!
//! ## Event flow
//!
//! *Write request*: `Admit` → SATA data-in transfer → FTL `plan_write` per
//! page → page jobs queued on their (channel, way) → per page: bus phase
//! (PROGRAM cmd + data + ECC) → chip t_PROG → status-poll bus phase → done.
//! Request completes when all its pages are programmed.
//!
//! *Read request*: `Admit` → SATA command FIS → FTL translate → per page:
//! bus phase (READ cmd) → chip t_R → bus phase (data out + ECC) → SATA
//! response chunk → done. Request completes when all chunks reach the host.
//!
//! Way interleaving emerges naturally: while one way's chip is busy in
//! t_R/t_PROG, the channel scheduler grants the bus to sibling ways.
//!
//! ## Admission: closed loop vs open loop
//!
//! By default requests are admitted *closed loop*: the device is refilled
//! to `queue_depth` as requests complete (`Admit` events). When an arrival
//! track is installed via [`SsdSim::set_arrivals`], admission is *open
//! loop*: request `i` enters at `arrivals[i]` (`Arrive` events) no matter
//! how the device is keeping up, so queueing delay — and therefore the
//! latency-vs-offered-load curve the E6 sweep measures — is visible.
//! Closed-loop runs are bit-identical to the pre-open-loop simulator.

use crate::config::{FtlKind, MapMode, SsdConfig};
use crate::controller::cache::{CacheOutcome, DramCache};
use crate::controller::channel::ChannelState;
use crate::controller::ecc::EccModel;
use crate::controller::ftl::demand::DemandPagedFtl;
use crate::controller::ftl::hybrid::HybridFtl;
use crate::controller::ftl::page_map::PageMapFtl;
use crate::controller::ftl::tiered::TieredFtl;
use crate::controller::ftl::{Ftl, FtlOp, MapAccess};
use crate::controller::nand_if::NandIf;
use crate::controller::sched::{self, SchedKind, WayScheduler};
use crate::controller::way::{JobPhase, PageJob, PageJobKind, WayState};
use crate::energy::{EnergyMeter, PowerModel};
use crate::host::link::{HostLink, HostLinkKind, MultiQueueLink, SubmissionQueues};
use crate::host::sata::SataLink;
use crate::host::trace::{
    CLASS_BACKGROUND, CLASS_NORMAL, NUM_CLASSES, Request, RequestKind, StreamTag,
};
use crate::iface::bus::{BusPhaseKind, BusTiming};
use crate::iface::timing::InterfaceKind;
use crate::nand::chip::{Chip, ChipOp};
use crate::nand::geometry::{Geometry, PageAddr};
use crate::observe::{BusUser, HostView, ObsState, ObserveReport};
use crate::coordinator::shard::{ChannelShard, ShardEv, ShardMsg};
use crate::sim::{Engine, EventKey, Hub, HubEmit, Model, RunResult, Scheduler, ShardedSim};
use crate::util::stats::Welford;
use crate::util::time::{mbps, Ps};

/// Marker for cache write-back eviction flushes: internal *dispatch*, but
/// the payload is deferred host data, so these programs count on the host
/// side of the write-amplification ratio.
pub const INTERNAL_REQ: u64 = u64::MAX;

/// Marker for coordinator-driven wear-leveling copy-back jobs (counted as
/// amplification, separately from GC).
pub const WL_REQ: u64 = u64::MAX - 1;

/// Marker for GC/merge copy-back jobs — the background ops of a write
/// plan (counted as amplification).
pub const GC_REQ: u64 = u64::MAX - 2;

/// Marker for SLC→MLC tier-migration copy-back jobs (counted as
/// amplification, separately from GC).
pub const MIG_REQ: u64 = u64::MAX - 3;

/// Marker for demand-paged mapping-tier jobs: translation-page fill reads
/// and dirty-eviction write-backs ([`crate::controller::ftl::demand`]).
/// Counted apart from both host and GC traffic; like cache flushes the
/// payload is metadata, not amplified host data. Any `req >= MAP_REQ` is
/// internal traffic and never completes a host request.
pub const MAP_REQ: u64 = u64::MAX - 4;

/// Simulation events.
#[derive(Debug, Clone, Copy)]
pub enum Ev {
    /// Try to admit more requests from the trace (respecting queue depth).
    Admit,
    /// Open-loop mode: admit every request whose arrival time has come,
    /// then re-arm for the next arrival (see [`SsdSim::set_arrivals`]).
    Arrive,
    /// A SATA transfer finished.
    SataDone { req: u64, phase: SataPhase },
    /// A channel bus phase finished.
    BusDone { ch: u16 },
    /// A chip array operation finished.
    ChipDone { ch: u16, way: u16 },
}

/// What a SATA completion means.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SataPhase {
    /// Write: host payload fully received into the controller FIFO.
    HostDataIn,
    /// Read: command FIS delivered; NAND work may start.
    ReadCmd,
    /// Read: one page-sized response chunk delivered to the host.
    ReadChunk,
}

/// What the bus is currently doing on a channel.
#[derive(Debug, Clone, Copy)]
enum BusCtx {
    /// Command phase issued to `way`; on completion the array op starts.
    CmdIssued { way: u16 },
    /// Read data-out phase from `way`; on completion the page is read.
    DataOut { way: u16 },
    /// Status poll of `way`; on completion the program/erase is done.
    StatusDone { way: u16 },
}

/// Per-request progress.
struct ReqState {
    kind: RequestKind,
    bytes: u32,
    pages_total: u32,
    pages_done: u32,
    chunks_done: u32,
    issued_at: Ps,
    /// Originating stream and priority class (stream 0 at the default
    /// class for untagged traces).
    stream: u16,
    class: u8,
    /// True if any of this request's write plans forced GC/merge work —
    /// its copy-back ops are queued ahead of the host program on the same
    /// way, so the request pays the GC stall (steady-state accounting).
    gc_hit: bool,
}

/// A host page operation parked behind a demand-mode map-cache miss,
/// resumed when the fill read for its translation page completes.
#[derive(Debug, Clone, Copy)]
struct MapWaiter {
    /// Physical page of the missed translation page (the fill's target).
    map_ppn: u64,
    /// The logical page whose dispatch is deferred.
    lpn: u64,
    /// Originating request id (host id, or `INTERNAL_REQ` for a deferred
    /// cache-eviction flush).
    req: u64,
    /// Write dispatch (`enqueue_write_plan`) vs read dispatch.
    write: bool,
    /// When the op parked, for map-stall accounting.
    since: Ps,
}

/// Aggregate simulation counters.
#[derive(Debug, Clone, Default)]
pub struct SimCounters {
    pub host_bytes: u64,
    pub requests_done: u64,
    pub pages_read: u64,
    pub pages_programmed: u64,
    pub blocks_erased: u64,
    pub internal_pages: u64,
    pub cache_hits: u64,
    /// Copy-back reads for GC/wear-leveling relocation (subset of
    /// `pages_read`).
    pub gc_pages_read: u64,
    /// GC/merge copy-back programs (subset of `pages_programmed`) — the
    /// write-amplification numerator beyond host traffic. Cache-flush
    /// programs are internal dispatch but deferred *host* data, so they
    /// are excluded here.
    pub gc_pages_programmed: u64,
    /// Coordinator-driven wear-leveling programs (subset of
    /// `pages_programmed`, disjoint from `gc_pages_programmed`).
    pub wl_pages_programmed: u64,
    /// Host requests whose write plan forced GC/merge work.
    pub gc_requests: u64,
    /// Tier-migration copy-back reads (subset of `pages_read`, disjoint
    /// from `gc_pages_read`).
    pub mig_pages_read: u64,
    /// Tier-migration programs (subset of `pages_programmed`, disjoint
    /// from the GC/WL program counters).
    pub mig_pages_programmed: u64,
    /// Host-read pages served from the SLC tier / the MLC tier (both zero
    /// when tiering is disabled; cache hits never reach either counter).
    pub slc_reads: u64,
    pub mlc_reads: u64,
    /// Mapping-tier lookups resolved from the map cache (all zero for
    /// fully-resident schemes, where translation never reaches the tier).
    pub map_hits: u64,
    /// Mapping-tier lookups that missed and issued a translation-page
    /// fill read (plus, for dirty evictions, a write-back program).
    pub map_misses: u64,
    /// Misses that stalled the host op until the fill completed (demand
    /// mode; the FMMU variant overlaps and never defers).
    pub map_deferred: u64,
    /// Translation-page fill reads completed (subset of `pages_read`,
    /// disjoint from `gc_pages_read`/`mig_pages_read`).
    pub map_pages_read: u64,
    /// Translation-page write-back programs completed (subset of
    /// `pages_programmed`, counted as amplification like GC).
    pub map_pages_programmed: u64,
    /// Total picoseconds host page ops spent parked waiting on map fills
    /// (demand mode only; divide by `map_deferred` for the mean stall).
    pub map_wait_ps: u64,
}

/// The DES model for one SSD + workload.
pub struct SsdSim {
    pub cfg: SsdConfig,
    pub geom: Geometry,
    channels: Vec<ChannelState>,
    bus_ctx: Vec<Option<BusCtx>>,
    /// Tiering: chips `[0, slc_chips)` are the SLC tier (0 = disabled).
    slc_chips: usize,
    /// Per-tier bus timing. ONFI-style controllers negotiate the timing
    /// mode per target die, so a shared channel bus clocks each transfer
    /// at its way's rate; when tiering is disabled both equal the
    /// channel's own timing and the routing is value-identical.
    slc_bus: BusTiming,
    mlc_bus: BusTiming,
    /// The host link ([`HostLinkKind`] in `[host]`; single-stream SATA by
    /// default, reuse-key-stable).
    link: Box<dyn HostLink>,
    ftl: Box<dyn Ftl>,
    cache: DramCache,
    trace: Vec<Request>,
    /// Open-loop arrival timestamps (one per trace entry, non-decreasing);
    /// empty = closed-loop queue-depth admission (the default).
    arrivals: Vec<Ps>,
    /// Stream tags (one per trace entry); empty = single-stream.
    streams: Vec<StreamTag>,
    /// Multi-queue closed-loop admission front end (`None` on the
    /// single-stream SATA path, and bypassed — like the global queue
    /// depth — by open-loop arrival admission).
    subq: Option<SubmissionQueues>,
    next_req: usize,
    /// Requests issued so far (across both admission paths).
    issued: usize,
    outstanding: u32,
    /// Request table indexed by request id (= trace index): dense and
    /// allocation-free on the hot path (perf pass, EXPERIMENTS.md §Perf).
    reqs: Vec<Option<ReqState>>,
    /// Pooled scratch for FTL write plans (GC/merge ops); cleared per plan,
    /// never reallocated in steady state (perf pass, EXPERIMENTS.md §Perf).
    ftl_ops: Vec<FtlOp>,
    /// Pooled scratch listing channels touched while fanning out one
    /// request's page jobs; kicked then cleared.
    kick_list: Vec<u16>,
    /// Pooled scratch for mapping-tier fill/write-back ops (separate from
    /// `ftl_ops` so a map consult never clobbers an in-progress plan).
    map_ops: Vec<FtlOp>,
    /// Host page ops parked behind demand-mode map-cache misses; drained
    /// by matching `map_ppn` when fill reads complete. Small (bounded by
    /// outstanding host pages), so linear scans are fine.
    map_waiters: Vec<MapWaiter>,
    /// Hub-mode job staging: `Some` only while a channel-sharded run is in
    /// flight (the channels themselves are moved into shards then). FTL
    /// plan output `(ch, way, job, gc_mark)` lands here instead of on a
    /// way queue and is released to the owning shard at the next window
    /// boundary by the commit step ([`SsdHub`]). `None` selects the
    /// classic in-place enqueue, byte-for-byte unchanged.
    shard_outbox: Option<Vec<(u16, u16, PageJob, bool)>>,
    pub counters: SimCounters,
    /// Per-stream accounting, indexed by stream id; all empty when the
    /// trace carries no stream track (single-tenant runs pay nothing).
    pub stream_class: Vec<u8>,
    pub stream_requests: Vec<u64>,
    pub stream_bytes: Vec<u64>,
    pub stream_latency_samples: Vec<Vec<f64>>,
    pub latency: Welford,
    /// Per-request latency samples in µs, in completion order — the raw
    /// material for the p50/p95/p99 columns of the load sweep (`report`,
    /// EXPERIMENTS.md §Load).
    pub latency_samples: Vec<f64>,
    /// Latency samples (µs) of requests whose write plan forced GC work /
    /// of all other requests — the split behind the GC-attributed p99
    /// inflation column (EXPERIMENTS.md §Steady-State). Fresh-drive runs
    /// leave the GC vector empty.
    pub gc_latency_samples: Vec<f64>,
    pub clean_latency_samples: Vec<f64>,
    pub power: PowerModel,
    pub energy: EnergyMeter,
    finished_at: Ps,
    /// Bottleneck observer (`[observe]`, [`crate::observe`]): per-resource
    /// occupancy accounting plus the optional trace timeline. `None` when
    /// disabled, which makes every hook a single `Option` branch — the
    /// zero-cost-when-off contract the bit-identity goldens in
    /// `rust/tests/observe.rs` pin down.
    obs: Option<Box<ObsState>>,
}

impl SsdSim {
    /// Build a simulator for `cfg` over `trace`.
    pub fn new(cfg: SsdConfig, trace: Vec<Request>) -> SsdSim {
        let nand = cfg.nand_timing();
        let geom = Geometry {
            channels: cfg.channels,
            ways: cfg.ways,
            blocks_per_chip: cfg.blocks_per_chip,
            pages_per_block: nand.pages_per_block,
            page_bytes: nand.page_bytes,
        };
        let slc_chips = cfg.tiering.slc_chips(cfg.chips()) as usize;
        let (slc_iface, mlc_iface) = Self::tier_ifaces(&cfg);
        let slc_nand = nand.slc_mode();
        let channels = (0..cfg.channels)
            .map(|ch| {
                let ways = (0..cfg.ways)
                    .map(|way| {
                        let chip = geom.chip_of(ch, way);
                        let t = if chip < slc_chips { slc_nand } else { nand };
                        WayState::new(Chip::new(t, geom.blocks_per_chip))
                    })
                    .collect();
                ChannelState::new(
                    NandIf::new(&cfg.params, cfg.iface),
                    EccModel::for_cell(cfg.cell),
                    ways,
                    Self::build_scheduler(&cfg),
                )
            })
            .collect();
        let logical_pages = cfg.logical_pages(geom.total_pages());
        let mut ftl: Box<dyn Ftl> = if cfg.tiering.enabled {
            Box::new(TieredFtl::new(
                geom,
                logical_pages,
                slc_chips,
                cfg.tiering.migrate_free_blocks,
            ))
        } else if cfg.mapping.mode != MapMode::Resident {
            // Validation guarantees page_map + no tiering for an active
            // [mapping] section, so this branch never shadows the others.
            Box::new(DemandPagedFtl::new(
                geom,
                logical_pages,
                cfg.mapping.cache_pages,
                cfg.mapping.entries_per_page as u64,
                cfg.mapping.mode == MapMode::Fmmu,
            ))
        } else {
            match cfg.ftl {
                FtlKind::PageMap => Box::new(PageMapFtl::new(geom, logical_pages)),
                FtlKind::Hybrid => Box::new(HybridFtl::new(geom, 8)),
            }
        };
        ftl.set_gc_tuning(cfg.steady.tuning());
        let power = if cfg.tiering.enabled {
            PowerModel::for_tiered(slc_iface, mlc_iface)
        } else {
            PowerModel::for_interface(cfg.iface)
        };
        let reqs = (0..trace.len()).map(|_| None).collect();
        let mut sim = SsdSim {
            bus_ctx: vec![None; cfg.channels as usize],
            channels,
            slc_chips,
            slc_bus: BusTiming::from_params(&cfg.params, slc_iface),
            mlc_bus: BusTiming::from_params(&cfg.params, mlc_iface),
            link: Self::build_link(&cfg),
            ftl,
            cache: DramCache::new(cfg.cache),
            trace,
            arrivals: Vec::new(),
            streams: Vec::new(),
            subq: None,
            next_req: 0,
            issued: 0,
            outstanding: 0,
            reqs,
            ftl_ops: Vec::new(),
            kick_list: Vec::new(),
            map_ops: Vec::new(),
            map_waiters: Vec::new(),
            shard_outbox: None,
            counters: SimCounters::default(),
            stream_class: Vec::new(),
            stream_requests: Vec::new(),
            stream_bytes: Vec::new(),
            stream_latency_samples: Vec::new(),
            latency: Welford::new(),
            latency_samples: Vec::new(),
            gc_latency_samples: Vec::new(),
            clean_latency_samples: Vec::new(),
            power,
            energy: EnergyMeter::default(),
            finished_at: Ps::ZERO,
            obs: None,
            geom,
            cfg,
        };
        sim.rebuild_admission();
        sim.rebuild_observer();
        sim
    }

    /// (Re)build the bottleneck observer from the current config: fresh
    /// accounting sized to the geometry when `[observe]` is enabled, `None`
    /// otherwise. The window-mark pitch on the timeline is the same
    /// conservative lookahead the sharded executor would use, so a Perfetto
    /// view shows where the parallel-commit horizons fall.
    fn rebuild_observer(&mut self) {
        self.obs = self.cfg.observe.enabled.then(|| {
            Box::new(ObsState::new(
                self.cfg.channels as usize,
                self.cfg.ways as usize,
                self.cfg.observe.timeline,
                self.window_lookahead(),
            ))
        });
    }

    /// Close the elapsed occupancy interval and reclassify every resource.
    /// Resource state is piecewise-constant between events, so one scan
    /// after each handled event makes the integer-ps accounting exact; the
    /// box is taken out and back so the scan can borrow the channel array.
    fn observe_scan(&mut self, now: Ps) {
        if let Some(mut obs) = self.obs.take() {
            let host = HostView {
                link_busy: self.link.busy_at(now),
            };
            obs.scan(now, &self.channels, host);
            self.obs = Some(obs);
        }
    }

    /// Consume the observer's report for this run (`None` when `[observe]`
    /// is disabled). Taking the state out keeps report assembly one-shot;
    /// [`reset`](Self::reset) rebuilds a fresh observer for the next run.
    pub fn take_observe_report(&mut self) -> Option<ObserveReport> {
        self.obs.take().map(|obs| obs.report())
    }

    /// Build the host link a config selects.
    fn build_link(cfg: &SsdConfig) -> Box<dyn HostLink> {
        match cfg.host.link {
            HostLinkKind::Sata => Box::new(SataLink::new(cfg.sata)),
            HostLinkKind::MultiQueue => {
                Box::new(MultiQueueLink::new(cfg.sata, cfg.host.queues))
            }
        }
    }

    /// Build the way-scheduling policy a config selects (one per channel).
    fn build_scheduler(cfg: &SsdConfig) -> Box<dyn WayScheduler> {
        sched::build(cfg.qos.scheduler, cfg.qos.weights)
    }

    /// Rebuild the closed-loop admission front end from the current config
    /// (called on construction, reset and [`set_streams`](Self::set_streams)).
    /// Queues are *primed* (filled with trace indices) once per run, in
    /// [`run_with`](Self::run_with), and only for closed-loop runs —
    /// open-loop admission bypasses them entirely.
    fn rebuild_admission(&mut self) {
        self.subq = match self.cfg.host.link {
            HostLinkKind::Sata => None,
            HostLinkKind::MultiQueue => Some(SubmissionQueues::new(
                self.cfg.host.queues,
                self.cfg.host.queue_depth,
                self.cfg.host.arbitration,
                self.cfg.host.weights,
            )),
        };
    }

    /// Stream tag of a trace request (stream 0 at the default class for
    /// untagged traces).
    fn stream_tag(&self, req: usize) -> StreamTag {
        self.streams.get(req).copied().unwrap_or(StreamTag {
            stream: 0,
            class: CLASS_NORMAL,
        })
    }

    /// Interface kind per tier: the `[tiering]` overrides, falling back to
    /// the top-level `iface` (and exactly that when tiering is disabled).
    fn tier_ifaces(cfg: &SsdConfig) -> (InterfaceKind, InterfaceKind) {
        (
            cfg.tiering.slc_iface.unwrap_or(cfg.iface),
            cfg.tiering.mlc_iface.unwrap_or(cfg.iface),
        )
    }

    /// Is the chip behind `(ch, way)` in the SLC tier?
    fn is_slc_way(&self, ch: u16, way: u16) -> bool {
        self.geom.chip_of(ch, way) < self.slc_chips
    }

    /// Bus timing for a transfer targeting `(ch, way)`: the channel's own
    /// timing when tiering is disabled, the target tier's otherwise.
    fn bus_timing_for(&self, ch: usize, way: usize) -> BusTiming {
        if self.slc_chips == 0 {
            self.channels[ch].bus.timing
        } else if self.is_slc_way(ch as u16, way as u16) {
            self.slc_bus
        } else {
            self.mlc_bus
        }
    }

    /// Pre-populate the FTL mapping for every page a read trace touches, as
    /// if the data had been written sequentially beforehand (fresh-SSD
    /// sequential fill). Costless in simulated time.
    pub fn prefill_for_reads(&mut self) {
        let page = self.geom.page_bytes as u64;
        let mut lpns: Vec<u64> = self
            .trace
            .iter()
            .filter(|r| r.kind == RequestKind::Read)
            .flat_map(|r| {
                let first = r.offset / page;
                let last = (r.offset + r.bytes as u64).div_ceil(page);
                first..last
            })
            .collect();
        lpns.sort_unstable();
        lpns.dedup();
        for lpn in lpns {
            if self.ftl.translate(lpn).is_none() {
                let _ = self.ftl.plan_write(lpn);
            }
        }
    }

    /// Precondition the drive for steady-state measurement: sequentially
    /// fill the entire exported logical space, mapping-only and costless in
    /// simulated time (like [`prefill_for_reads`](Self::prefill_for_reads)).
    /// Every subsequent host write then invalidates an old page, so GC
    /// reaches its sustained regime inside the measured window instead of
    /// after a multi-pass warm-up.
    pub fn precondition_fill(&mut self) {
        // The FTL's own exported capacity, not the config arithmetic: the
        // hybrid FTL reserves log blocks out of its range (config
        // validation rejects steady sizing for it, but a direct caller
        // must not overrun either). Equal to `cfg.logical_pages` for the
        // page-map FTL.
        let logical = self.ftl.logical_capacity();
        debug_assert!(self.ftl_ops.is_empty());
        for lpn in 0..logical {
            // A first-touch sequential fill produces no background ops
            // (nothing to reclaim); any that appear are mapping-side
            // bookkeeping already applied, with no simulated cost.
            self.ftl.plan_write_into(lpn, &mut self.ftl_ops);
            self.ftl_ops.clear();
        }
    }

    /// Write amplification factor: total NAND programs over host-attributed
    /// programs. Cache write-back flushes carry deferred host data, so they
    /// count on the host side; GC/wear-leveling copy-back and mapping-tier
    /// write-backs (metadata, not host data) amplify.
    /// 1.0 for runs with no copy-back traffic (and for read-only runs,
    /// which program nothing).
    pub fn waf(&self) -> f64 {
        let total = self.counters.pages_programmed;
        let internal = self.counters.gc_pages_programmed
            + self.counters.wl_pages_programmed
            + self.counters.mig_pages_programmed
            + self.counters.map_pages_programmed;
        let host = total - internal;
        if host == 0 {
            1.0
        } else {
            total as f64 / host as f64
        }
    }

    /// Largest measured per-chip P/E spread ([`Chip::wear_spread`]) across
    /// the array at end of run.
    pub fn max_wear_spread(&self) -> u32 {
        self.channels
            .iter()
            .flat_map(|c| c.ways.iter())
            .map(|w| w.chip.wear_spread())
            .max()
            .unwrap_or(0)
    }

    /// Logical pages spanned by a request.
    fn lpns(&self, r: &Request) -> std::ops::Range<u64> {
        let page = self.geom.page_bytes as u64;
        (r.offset / page)..(r.offset + r.bytes as u64).div_ceil(page)
    }

    fn enqueue_ftl_op(&mut self, op: FtlOp, req: u64) -> (u16, u16) {
        let (kind, ppn_for_addr, block_page) = match op {
            FtlOp::ReadPage { ppn }
            | FtlOp::MigReadPage { ppn }
            | FtlOp::MapReadPage { ppn } => (PageJobKind::Read, ppn, None),
            FtlOp::ProgramPage { ppn }
            | FtlOp::MigProgramPage { ppn }
            | FtlOp::MapProgramPage { ppn } => (PageJobKind::Program, ppn, None),
            FtlOp::EraseBlock { chip, block } => {
                let (channel, way) = self.geom.chip_addr(chip);
                (PageJobKind::Erase, 0, Some((channel, way, block)))
            }
        };
        let (ch, way, block, page) = if let Some((ch, way, block)) = block_page {
            (ch, way, block, 0)
        } else {
            let a = self.geom.page_addr(ppn_for_addr);
            (a.channel, a.way, a.block, a.page)
        };
        // Background traffic (GC, wear leveling, migration, cache flush,
        // map fills) carries an explicit lowest class instead of relying
        // on implicit queue ordering; host jobs inherit their request's
        // stream/class.
        let (stream, class) = if req >= MAP_REQ {
            (u16::MAX, CLASS_BACKGROUND)
        } else {
            let st = self.reqs[req as usize].as_ref().expect("unknown request");
            (st.stream, st.class.min(CLASS_BACKGROUND))
        };
        let job = PageJob {
            req,
            stream,
            class,
            kind,
            block,
            page,
            bytes: self.geom.page_bytes,
            phase: JobPhase::Queued,
        };
        if let Some(outbox) = self.shard_outbox.as_mut() {
            // Hub mode: the way queues live inside the channel shards; the
            // commit step ships the job over at the window boundary.
            outbox.push((ch, way, job, false));
        } else {
            self.channels[ch as usize].ways[way as usize].push(job);
        }
        (ch, way)
    }

    /// Plan one logical-page write via the FTL and enqueue its background
    /// ops plus the host program; touched channels are appended to the
    /// pooled kick list. Allocation-free in steady state. `now` is only
    /// consumed by the observer's GC-trigger mark; the plan itself is
    /// time-independent.
    fn enqueue_write_plan(&mut self, lpn: u64, req: u64, now: Ps) {
        self.ftl_ops.clear();
        let target = self.ftl.plan_write_into(lpn, &mut self.ftl_ops);
        // GC-stall attribution: a host request whose plan carries
        // background ops (GC, migration) waits behind them on the same way.
        if req < MAP_REQ && !self.ftl_ops.is_empty() {
            if let Some(st) = self.reqs[req as usize].as_mut() {
                if !st.gc_hit {
                    st.gc_hit = true;
                    self.counters.gc_requests += 1;
                }
            }
        }
        // Index loop: enqueue_ftl_op needs `&mut self` (ops are Copy).
        let mut i = 0;
        while i < self.ftl_ops.len() {
            let op = self.ftl_ops[i];
            let marker = match op {
                FtlOp::MigReadPage { .. } | FtlOp::MigProgramPage { .. } => MIG_REQ,
                _ => GC_REQ,
            };
            let (ch, _) = self.enqueue_ftl_op(op, marker);
            // One GC/migration mark per triggering plan, on the channel of
            // its first background op (where the barrier forms). In hub
            // mode the observer lives inside the shard, so the mark rides
            // the job and lands when the shard enqueues it at the window
            // boundary — a bounded, thread-invariant timestamp shift
            // (DESIGN.md §Engine).
            if i == 0 {
                if let Some(obs) = self.obs.as_mut() {
                    obs.gc_trigger(ch as usize, now);
                } else if let Some(outbox) = self.shard_outbox.as_mut() {
                    if let Some(last) = outbox.last_mut() {
                        last.3 = true;
                    }
                }
            }
            self.kick_list.push(ch);
            i += 1;
        }
        let (ch, _) = self.enqueue_ftl_op(FtlOp::ProgramPage { ppn: target }, req);
        self.kick_list.push(ch);
    }

    /// Kick every channel recorded in the pooled kick list, then clear it.
    /// In hub mode there is nothing to kick — the shards wake themselves
    /// on the `Enqueue` delivery — so the list is just cleared.
    fn kick_touched(&mut self, sched: &mut Scheduler<Ev>) {
        if self.shard_outbox.is_some() {
            self.kick_list.clear();
            return;
        }
        let mut i = 0;
        while i < self.kick_list.len() {
            let ch = self.kick_list[i];
            self.kick_channel(ch, sched);
            i += 1;
        }
        self.kick_list.clear();
    }

    /// Consult the demand-paged mapping tier before dispatching a host
    /// page op on `lpn` ([`Ftl::map_access`]). A miss enqueues its fill
    /// read — and any dirty-eviction write-back — as `MAP_REQ` page jobs
    /// on the kick list, contending for channel/way like all other
    /// traffic. Returns true when the op must be *deferred* (demand-mode
    /// miss): the caller parks it and [`Self::map_fill_completed`] resumes
    /// it when the fill lands. Always false for resident schemes and the
    /// overlapping FMMU variant.
    fn map_gate(&mut self, lpn: u64, write: bool, req: u64, now: Ps) -> bool {
        self.map_ops.clear();
        match self.ftl.map_access(lpn, write, &mut self.map_ops) {
            MapAccess::Resident => false,
            MapAccess::Hit => {
                self.counters.map_hits += 1;
                false
            }
            MapAccess::Miss { map_ppn, defer } => {
                // An in-flight fill for the same translation page appends
                // no new ops; the deferred op still parks behind it.
                self.counters.map_misses += 1;
                let mut i = 0;
                while i < self.map_ops.len() {
                    let op = self.map_ops[i];
                    let (ch, _) = self.enqueue_ftl_op(op, MAP_REQ);
                    self.kick_list.push(ch);
                    i += 1;
                }
                if defer {
                    self.counters.map_deferred += 1;
                    self.map_waiters.push(MapWaiter {
                        map_ppn,
                        lpn,
                        req,
                        write,
                        since: now,
                    });
                }
                defer
            }
        }
    }

    /// A `MAP_REQ` fill read finished for the translation page stored at
    /// `map_ppn`: mark it resident and resume every host op parked on it,
    /// in arrival order. Resumption never re-consults the tier — the
    /// access already hit (and, for writes, dirtied) the cache entry when
    /// the op parked.
    fn map_fill_completed(&mut self, map_ppn: u64, sched: &mut Scheduler<Ev>) {
        self.ftl.map_fill_done(map_ppn);
        debug_assert!(self.kick_list.is_empty());
        let now = sched.now();
        let mut i = 0;
        while i < self.map_waiters.len() {
            if self.map_waiters[i].map_ppn != map_ppn {
                i += 1;
                continue;
            }
            let w = self.map_waiters.remove(i);
            self.counters.map_wait_ps += (now - w.since).as_ps() as u64;
            if w.write {
                self.enqueue_write_plan(w.lpn, w.req, now);
            } else {
                self.issue_read_lpn(w.lpn, w.req);
            }
        }
        self.kick_touched(sched);
    }

    /// Dispatch NAND work for a write request whose payload has arrived.
    fn start_write_pages(&mut self, req: u64, sched: &mut Scheduler<Ev>) {
        let r = self.trace[req as usize];
        debug_assert!(self.kick_list.is_empty());
        for lpn in self.lpns(&r) {
            match self.cache.write(lpn) {
                CacheOutcome::Hit => {
                    // Absorbed by DRAM; page complete immediately.
                    self.counters.cache_hits += 1;
                    self.page_programmed(req, sched);
                    continue;
                }
                CacheOutcome::Miss { evict_flush } => {
                    // This write still occupies a cache slot; the page is
                    // considered done when cached, but any dirty eviction
                    // must be flushed to NAND as internal traffic (gated
                    // on the victim's own translation page like any
                    // other mapping write).
                    if let Some(victim) = evict_flush {
                        if !self.map_gate(victim, true, INTERNAL_REQ, sched.now()) {
                            self.enqueue_write_plan(victim, INTERNAL_REQ, sched.now());
                        }
                    }
                    self.page_programmed(req, sched);
                    continue;
                }
                CacheOutcome::Bypass => {}
            }
            if !self.map_gate(lpn, true, req, sched.now()) {
                self.enqueue_write_plan(lpn, req, sched.now());
            }
        }
        self.kick_touched(sched);
    }

    /// Translate and enqueue the NAND read for one host page — the tail
    /// of the read path, after the DRAM cache and mapping tier have both
    /// had their say (also the resume target for deferred reads).
    fn issue_read_lpn(&mut self, lpn: u64, req: u64) {
        let ppn = self
            .ftl
            .translate(lpn)
            .expect("read of never-written lpn; call prefill_for_reads");
        if self.slc_chips > 0 {
            let a = self.geom.page_addr(ppn);
            if self.is_slc_way(a.channel, a.way) {
                self.counters.slc_reads += 1;
            } else {
                self.counters.mlc_reads += 1;
            }
        }
        let (ch, _) = self.enqueue_ftl_op(FtlOp::ReadPage { ppn }, req);
        self.kick_list.push(ch);
    }

    /// Dispatch NAND work for a read request after its command FIS.
    fn start_read_pages(&mut self, req: u64, sched: &mut Scheduler<Ev>) {
        let r = self.trace[req as usize];
        debug_assert!(self.kick_list.is_empty());
        for lpn in self.lpns(&r) {
            match self.cache.read(lpn) {
                CacheOutcome::Hit => {
                    self.counters.cache_hits += 1;
                    // Serve straight from DRAM: only the SATA chunk remains.
                    self.send_read_chunk(req, sched);
                    continue;
                }
                CacheOutcome::Miss { evict_flush } => {
                    // The miss fill occupies a cache slot; a dirty eviction
                    // must be flushed to NAND *before* the fill read is
                    // issued, or the deferred host data would be silently
                    // dropped (this path used to discard the flush).
                    if let Some(victim) = evict_flush {
                        if !self.map_gate(victim, true, INTERNAL_REQ, sched.now()) {
                            self.enqueue_write_plan(victim, INTERNAL_REQ, sched.now());
                        }
                    }
                }
                CacheOutcome::Bypass => {}
            }
            if !self.map_gate(lpn, false, req, sched.now()) {
                self.issue_read_lpn(lpn, req);
            }
        }
        self.kick_touched(sched);
    }

    /// A host page program finished (or was absorbed); update the request.
    fn page_programmed(&mut self, req: u64, sched: &mut Scheduler<Ev>) {
        let done = {
            let st = self.reqs[req as usize].as_mut().expect("unknown request");
            st.pages_done += 1;
            st.pages_done == st.pages_total
        };
        if done {
            self.complete_request(req, sched);
        }
    }

    /// Queue one read-response chunk to the host.
    fn send_read_chunk(&mut self, req: u64, sched: &mut Scheduler<Ev>) {
        let bytes = self.geom.page_bytes as u64;
        let stream = self.stream_tag(req as usize).stream;
        let (_, done_at) = self.link.reserve(sched.now(), stream, bytes, false);
        sched.at(
            done_at,
            Ev::SataDone {
                req,
                phase: SataPhase::ReadChunk,
            },
        );
    }

    fn complete_request(&mut self, req: u64, sched: &mut Scheduler<Ev>) {
        let st = self.reqs[req as usize].take().expect("unknown request");
        self.outstanding -= 1;
        self.counters.requests_done += 1;
        self.counters.host_bytes += st.bytes as u64;
        let lat_us = (sched.now() - st.issued_at).as_us_f64();
        self.latency.push(lat_us);
        self.latency_samples.push(lat_us);
        if st.gc_hit {
            self.gc_latency_samples.push(lat_us);
        } else {
            self.clean_latency_samples.push(lat_us);
        }
        if !self.stream_class.is_empty() {
            let s = st.stream as usize;
            self.stream_requests[s] += 1;
            self.stream_bytes[s] += st.bytes as u64;
            self.stream_latency_samples[s].push(lat_us);
        }
        self.finished_at = sched.now();
        // Open-loop admission is arrival-driven (and bypasses the
        // submission queues, whose depth bookkeeping only runs closed
        // loop); a completion-time Admit would be a guaranteed no-op
        // event on the hot path.
        if self.arrivals.is_empty() {
            if let Some(q) = self.subq.as_mut() {
                q.complete(st.stream);
            }
            sched.now_ev(Ev::Admit);
        }
    }

    /// Observer attribution of a bus grant, from the owning job's request
    /// marker: map-fill traffic gets its own stall cause, everything else
    /// splits host vs internal (GC/WL/migration/cache-flush).
    pub(crate) fn bus_user(req: u64) -> BusUser {
        if req == MAP_REQ {
            BusUser::MapFill
        } else if req >= MIG_REQ {
            BusUser::Internal
        } else {
            BusUser::Host
        }
    }

    /// Grant the channel bus to the next way that wants it.
    fn kick_channel(&mut self, ch: u16, sched: &mut Scheduler<Ev>) {
        let chi = ch as usize;
        let now = sched.now();
        if !self.channels[chi].bus.is_free(now) || self.bus_ctx[chi].is_some() {
            return; // BusDone will re-kick.
        }
        let Some(grant) = self.channels[chi].next_grant(now) else {
            return; // ChipDone events will re-kick when array ops finish.
        };
        let wi = grant.way;
        // Transfers clock at the target way's tier rate (the channel's own
        // timing when tiering is disabled — value-identical routing).
        let bt = self.bus_timing_for(chi, wi);
        let chan = &mut self.channels[chi];
        let way = &mut chan.ways[wi];
        if let Some(job) = way.inflight {
            match job.phase {
                JobPhase::AwaitXferOut => {
                    // Read data-out: page + spare over the bus, ECC decode
                    // pipelined on the tail.
                    let nand = way.chip.timing;
                    let bytes = nand.transfer_bytes();
                    let ecc = chan.ecc.page_latency(nand.page_bytes);
                    let xfer = bt.data_transfer(bytes) + ecc;
                    chan.bus.data_bytes += bytes as u64;
                    let done = chan.bus.occupy(now, xfer);
                    self.bus_ctx[chi] = Some(BusCtx::DataOut { way: wi as u16 });
                    if let Some(obs) = self.obs.as_mut() {
                        obs.bus_granted(
                            chi,
                            wi as u16,
                            Self::bus_user(job.req),
                            BusPhaseKind::DataOut,
                            now,
                            done,
                        );
                    }
                    sched.at(done, Ev::BusDone { ch });
                }
                JobPhase::AwaitStatus => {
                    let dur = bt.status_poll() + self.cfg.program_status_overhead;
                    let done = chan.bus.occupy_cmd(now, dur);
                    self.bus_ctx[chi] = Some(BusCtx::StatusDone { way: wi as u16 });
                    if let Some(obs) = self.obs.as_mut() {
                        obs.bus_granted(
                            chi,
                            wi as u16,
                            Self::bus_user(job.req),
                            BusPhaseKind::Status,
                            now,
                            done,
                        );
                    }
                    sched.at(done, Ev::BusDone { ch });
                }
                other => unreachable!("inflight job in bus-wanting phase {other:?}"),
            }
            return;
        }
        // Dispatch the granted job from the queue (index 0 — FIFO — under
        // the default policy; QoS policies may pull a later job forward,
        // never across a background barrier). `take_job` keeps the way's
        // per-class counts in sync with the queue.
        let mut job = way.take_job(grant.job).expect("grant names a queued job");
        let nand = way.chip.timing;
        let dur = match job.kind {
            PageJobKind::Read => bt.read_cmd(),
            PageJobKind::Program => {
                // PROGRAM = cmd/addr + data-in (+ ECC encode pipelined).
                let bytes = nand.transfer_bytes();
                chan.bus.data_bytes += bytes as u64;
                bt.program_cmd() + bt.data_transfer(bytes) + chan.ecc.page_latency(nand.page_bytes)
            }
            PageJobKind::Erase => bt.erase_cmd(),
        };
        let done = chan.bus.occupy_cmd(now, dur);
        job.phase = JobPhase::ArrayBusy; // array op starts at phase end
        way.inflight = Some(job);
        self.bus_ctx[chi] = Some(BusCtx::CmdIssued { way: wi as u16 });
        if let Some(obs) = self.obs.as_mut() {
            obs.job_started(chi, wi as u16, job.kind, now);
            obs.bus_granted(
                chi,
                wi as u16,
                Self::bus_user(job.req),
                BusPhaseKind::Cmd,
                now,
                done,
            );
        }
        sched.at(done, Ev::BusDone { ch });
    }

    fn on_bus_done(&mut self, ch: u16, sched: &mut Scheduler<Ev>) {
        let chi = ch as usize;
        let ctx = self.bus_ctx[chi].take().expect("BusDone without context");
        if let Some(obs) = self.obs.as_mut() {
            obs.bus_released(chi, sched.now());
        }
        match ctx {
            BusCtx::CmdIssued { way } => {
                let wi = way as usize;
                let job = self.channels[chi].ways[wi]
                    .inflight
                    .expect("cmd issued to idle way");
                let op = match job.kind {
                    PageJobKind::Read => ChipOp::ReadFetch {
                        block: job.block,
                        page: job.page,
                    },
                    PageJobKind::Program => ChipOp::Program {
                        block: job.block,
                        page: job.page,
                    },
                    PageJobKind::Erase => ChipOp::Erase { block: job.block },
                };
                let w = &mut self.channels[chi].ways[wi];
                let dur = w.chip.start(sched.now(), op);
                w.array_done_at = sched.now() + dur;
                let done = w.array_done_at;
                sched.at(done, Ev::ChipDone { ch, way });
                if let Some(obs) = self.obs.as_mut() {
                    obs.array_started(chi, way, job.kind, sched.now(), done);
                }
            }
            BusCtx::DataOut { way } => {
                // Read page fully transferred to the controller.
                let wi = way as usize;
                let job = self.channels[chi].ways[wi]
                    .inflight
                    .take()
                    .expect("data-out from idle way");
                if let Some(obs) = self.obs.as_mut() {
                    obs.job_completed(chi, way, job.kind, sched.now());
                }
                self.counters.pages_read += 1;
                if job.req == MAP_REQ {
                    // A translation-page fill landed: the mapping tier
                    // marks it resident and any parked host ops resume.
                    self.counters.internal_pages += 1;
                    self.counters.map_pages_read += 1;
                    let ppn = self.geom.ppn(PageAddr {
                        channel: ch,
                        way,
                        block: job.block,
                        page: job.page,
                    });
                    self.map_fill_completed(ppn, sched);
                } else if job.req >= MIG_REQ {
                    self.counters.internal_pages += 1;
                    if job.req == MIG_REQ {
                        self.counters.mig_pages_read += 1;
                    } else if job.req != INTERNAL_REQ {
                        self.counters.gc_pages_read += 1;
                    }
                } else {
                    self.send_read_chunk(job.req, sched);
                }
            }
            BusCtx::StatusDone { way } => {
                let wi = way as usize;
                let job = self.channels[chi].ways[wi]
                    .inflight
                    .take()
                    .expect("status from idle way");
                if let Some(obs) = self.obs.as_mut() {
                    obs.job_completed(chi, way, job.kind, sched.now());
                }
                match job.kind {
                    PageJobKind::Program => {
                        self.counters.pages_programmed += 1;
                        self.energy.add_nand_program(&self.power.clone(), 1);
                        if job.req >= MAP_REQ {
                            self.counters.internal_pages += 1;
                            // Cache-flush programs (INTERNAL_REQ) carry
                            // deferred host data: internal dispatch, host
                            // side of the amplification split.
                            if job.req == GC_REQ {
                                self.counters.gc_pages_programmed += 1;
                                self.energy.add_gc_program(&self.power.clone(), 1);
                            } else if job.req == WL_REQ {
                                self.counters.wl_pages_programmed += 1;
                                self.energy.add_gc_program(&self.power.clone(), 1);
                            } else if job.req == MIG_REQ {
                                self.counters.mig_pages_programmed += 1;
                                self.energy.add_mig_program(&self.power.clone(), 1);
                            } else if job.req == MAP_REQ {
                                // Translation-page write-back: metadata
                                // amplification, like GC for the WAF split.
                                self.counters.map_pages_programmed += 1;
                            }
                        } else {
                            self.page_programmed(job.req, sched);
                        }
                    }
                    PageJobKind::Erase => {
                        self.counters.blocks_erased += 1;
                        self.maybe_wear_level(ch, way, sched);
                    }
                    PageJobKind::Read => unreachable!("reads have no status phase"),
                }
            }
        }
        self.kick_channel(ch, sched);
    }

    /// Steady-state wear leveling, driven by measured chip state: after an
    /// erase completes on (ch, way), if that chip's P/E spread
    /// ([`Chip::wear_spread`]) exceeds the `[steady]` limit, ask the FTL to
    /// relocate its coldest full block. The copy-back ops enter the DES as
    /// real [`WL_REQ`] page jobs, so leveling contends with host traffic on
    /// the same channel and way. The hook only runs when the `[steady]`
    /// section is enabled *and* the threshold is nonzero — fresh-drive runs
    /// take the early return and stay bit-identical.
    fn maybe_wear_level(&mut self, ch: u16, way: u16, sched: &mut Scheduler<Ev>) {
        let threshold = self.cfg.steady.wear_level_spread;
        if !self.cfg.steady.enabled || threshold == 0 {
            return;
        }
        let spread = self.channels[ch as usize].ways[way as usize]
            .chip
            .wear_spread();
        self.wear_level_with_spread(ch, way, spread, sched);
    }

    /// Spread-supplied variant of [`Self::maybe_wear_level`]: in hub mode
    /// the chip lives inside its shard, so the erase completion message
    /// carries the measured spread instead of reading it here.
    fn wear_level_with_spread(&mut self, ch: u16, way: u16, spread: u32, sched: &mut Scheduler<Ev>) {
        let threshold = self.cfg.steady.wear_level_spread;
        if !self.cfg.steady.enabled || threshold == 0 || spread <= threshold {
            return;
        }
        let chip = self.geom.chip_of(ch, way);
        self.ftl_ops.clear();
        if !self.ftl.plan_wear_level_into(chip, &mut self.ftl_ops) {
            return;
        }
        debug_assert!(self.kick_list.is_empty());
        let mut i = 0;
        while i < self.ftl_ops.len() {
            let op = self.ftl_ops[i];
            let (c, _) = self.enqueue_ftl_op(op, WL_REQ);
            self.kick_list.push(c);
            i += 1;
        }
        self.kick_touched(sched);
    }

    fn on_chip_done(&mut self, ch: u16, way: u16, sched: &mut Scheduler<Ev>) {
        let w = &mut self.channels[ch as usize].ways[way as usize];
        if let Some(job) = &mut w.inflight {
            debug_assert_eq!(job.phase, JobPhase::ArrayBusy);
            job.phase = match job.kind {
                PageJobKind::Read => {
                    self.energy.add_nand_read(&self.power.clone(), 0); // counted at xfer
                    JobPhase::AwaitXferOut
                }
                PageJobKind::Program | PageJobKind::Erase => JobPhase::AwaitStatus,
            };
        }
        self.kick_channel(ch, sched);
    }

    // ---- hub-side halves of the shard message protocol -----------------
    //
    // Channel-sharded runs split every NAND completion in two: the shard
    // keeps the bus/way/chip mechanics, and ships a message the commit
    // step replays here against the global state (counters, energy, FTL,
    // mapping tier, cache, host link). Each handler below is the exact
    // global half of the corresponding `on_bus_done` arm.

    /// A shard finished a read data-out ([`ShardMsg::ReadOut`]).
    fn shard_read_out(
        &mut self,
        ch: u16,
        req: u64,
        way: u16,
        block: u32,
        page: u32,
        sched: &mut Scheduler<Ev>,
    ) {
        self.counters.pages_read += 1;
        if req == MAP_REQ {
            self.counters.internal_pages += 1;
            self.counters.map_pages_read += 1;
            let ppn = self.geom.ppn(PageAddr {
                channel: ch,
                way,
                block,
                page,
            });
            self.map_fill_completed(ppn, sched);
        } else if req >= MIG_REQ {
            self.counters.internal_pages += 1;
            if req == MIG_REQ {
                self.counters.mig_pages_read += 1;
            } else if req != INTERNAL_REQ {
                self.counters.gc_pages_read += 1;
            }
        } else {
            self.send_read_chunk(req, sched);
        }
    }

    /// A shard finished a program status poll ([`ShardMsg::Programmed`]).
    fn shard_programmed(&mut self, req: u64, sched: &mut Scheduler<Ev>) {
        self.counters.pages_programmed += 1;
        self.energy.add_nand_program(&self.power.clone(), 1);
        if req >= MAP_REQ {
            self.counters.internal_pages += 1;
            if req == GC_REQ {
                self.counters.gc_pages_programmed += 1;
                self.energy.add_gc_program(&self.power.clone(), 1);
            } else if req == WL_REQ {
                self.counters.wl_pages_programmed += 1;
                self.energy.add_gc_program(&self.power.clone(), 1);
            } else if req == MIG_REQ {
                self.counters.mig_pages_programmed += 1;
                self.energy.add_mig_program(&self.power.clone(), 1);
            } else if req == MAP_REQ {
                self.counters.map_pages_programmed += 1;
            }
        } else {
            self.page_programmed(req, sched);
        }
    }

    /// A shard finished an erase status poll ([`ShardMsg::Erased`]);
    /// `spread` is the chip's P/E spread measured shard-side (0 when the
    /// wear-level hook is disabled, matching its classic early return).
    fn shard_erased(&mut self, ch: u16, way: u16, spread: u32, sched: &mut Scheduler<Ev>) {
        self.counters.blocks_erased += 1;
        self.wear_level_with_spread(ch, way, spread, sched);
    }

    /// Closed-loop admission. Single-stream path: refill the device to
    /// its global queue depth in trace order. Multi-queue path: let the
    /// submission-queue front end fetch — per-queue depth, queue
    /// arbitration — until no queue is eligible. A no-op in open-loop
    /// mode, where [`arrive`](Self::arrive) drives admission from the
    /// arrival track instead.
    fn admit(&mut self, sched: &mut Scheduler<Ev>) {
        if !self.arrivals.is_empty() {
            return;
        }
        if self.subq.is_some() {
            loop {
                let Some(idx) = self.subq.as_mut().and_then(SubmissionQueues::fetch) else {
                    break;
                };
                self.issue_req(idx as usize, sched);
            }
        } else {
            while self.outstanding < self.cfg.queue_depth && self.next_req < self.trace.len() {
                let idx = self.next_req;
                self.next_req += 1;
                self.issue_req(idx, sched);
            }
        }
    }

    /// Open-loop admission: admit every request whose arrival time has
    /// come (the queue is unbounded — under overload, latency grows
    /// without bound, which is exactly the saturation signal the load
    /// sweep measures; submission-queue depths are bypassed for the same
    /// reason), then re-arm for the next arrival.
    fn arrive(&mut self, sched: &mut Scheduler<Ev>) {
        while self.next_req < self.trace.len() && self.arrivals[self.next_req] <= sched.now() {
            let idx = self.next_req;
            self.next_req += 1;
            self.issue_req(idx, sched);
        }
        if self.next_req < self.trace.len() {
            sched.at(self.arrivals[self.next_req], Ev::Arrive);
        }
    }

    /// Admit trace request `idx` now: create its state and start its host
    /// command/data phase.
    fn issue_req(&mut self, idx: usize, sched: &mut Scheduler<Ev>) {
        let id = idx as u64;
        let r = self.trace[idx];
        let tag = self.stream_tag(idx);
        self.issued += 1;
        self.outstanding += 1;
        let pages = self.lpns(&r).count() as u32;
        self.reqs[idx] = Some(ReqState {
            kind: r.kind,
            bytes: r.bytes,
            pages_total: pages,
            pages_done: 0,
            chunks_done: 0,
            issued_at: sched.now(),
            stream: tag.stream,
            class: tag.class,
            gc_hit: false,
        });
        match r.kind {
            RequestKind::Write => {
                let (_, done) = self
                    .link
                    .reserve(sched.now(), tag.stream, r.bytes as u64, true);
                sched.at(
                    done,
                    Ev::SataDone {
                        req: id,
                        phase: SataPhase::HostDataIn,
                    },
                );
            }
            RequestKind::Read => {
                let (_, done) = self.link.reserve(sched.now(), tag.stream, 0, true);
                sched.at(
                    done,
                    Ev::SataDone {
                        req: id,
                        phase: SataPhase::ReadCmd,
                    },
                );
            }
        }
    }

    /// Switch this run to open-loop admission: request `i` enters the
    /// device at `arrivals[i]` regardless of completions. Pass an empty
    /// slice (or call [`reset`](Self::reset)) to restore the default
    /// closed-loop admission; closed-loop behaviour is bit-identical to a
    /// simulator that never had an arrival track (tested below).
    pub fn set_arrivals(&mut self, arrivals: &[Ps]) {
        assert!(
            arrivals.is_empty() || arrivals.len() == self.trace.len(),
            "arrival track length mismatch: {} arrivals for {} requests",
            arrivals.len(),
            self.trace.len()
        );
        debug_assert!(
            arrivals.windows(2).all(|w| w[0] <= w[1]),
            "arrivals must be non-decreasing"
        );
        self.arrivals.clear();
        self.arrivals.extend_from_slice(arrivals);
    }

    /// Install a per-request stream track: request `i` belongs to
    /// submission queue / tenant `streams[i].stream` at priority class
    /// `streams[i].class`, enabling per-stream latency accounting and the
    /// QoS way schedulers' class decisions. Pass an empty slice (or call
    /// [`reset`](Self::reset)) to restore single-stream behaviour, which
    /// is bit-identical to a simulator that never had a stream track.
    pub fn set_streams(&mut self, streams: &[StreamTag]) {
        assert!(
            streams.is_empty() || streams.len() == self.trace.len(),
            "stream track length mismatch: {} tags for {} requests",
            streams.len(),
            self.trace.len()
        );
        // Same rule as the trace parser and merge_streams: class 3 is the
        // device's background class; a host stream tagged with it would
        // silently become a plan-order barrier and be served from the
        // background scheduling budget.
        assert!(
            streams.iter().all(|t| t.class < CLASS_BACKGROUND),
            "host stream classes must be < {CLASS_BACKGROUND} (background is reserved)"
        );
        let nstreams = streams
            .iter()
            .map(|t| t.stream as usize + 1)
            .max()
            .unwrap_or(0);
        if self.cfg.host.link == HostLinkKind::MultiQueue {
            assert!(
                nstreams <= self.cfg.host.queues as usize,
                "stream ids reach {} but host.queues = {}",
                nstreams,
                self.cfg.host.queues
            );
        }
        self.streams.clear();
        self.streams.extend_from_slice(streams);
        self.stream_class = vec![CLASS_NORMAL; nstreams];
        let mut tagged = vec![false; nstreams];
        for t in &self.streams {
            let s = t.stream as usize;
            if !tagged[s] {
                tagged[s] = true;
                self.stream_class[s] = t.class;
            }
        }
        self.stream_requests = vec![0; nstreams];
        self.stream_bytes = vec![0; nstreams];
        self.stream_latency_samples = vec![Vec::new(); nstreams];
        self.rebuild_admission();
    }

    /// All requests issued and completed?
    pub fn is_done(&self) -> bool {
        self.issued == self.trace.len() && self.outstanding == 0
    }

    /// Simulated time of the last request completion.
    pub fn finished_at(&self) -> Ps {
        self.finished_at
    }

    /// Host-visible bandwidth over the run.
    pub fn bandwidth_mbps(&self) -> f64 {
        mbps(self.counters.host_bytes, self.finished_at)
    }

    /// The structural fingerprint that gates simulator reuse: two configs
    /// with equal keys size every array/table (channels, ways, per-chip
    /// block tables, FTL mapping tables, logical capacity) identically, so
    /// [`SsdSim::reset`] can retarget an existing simulator instead of
    /// rebuilding it. Interface, cell timing, SATA generation, cache and
    /// queue-depth settings may all differ — they are overwritten in place.
    /// The tier partition and migration threshold are FTL construction
    /// parameters, so they are part of the key (0/0 when tiering is
    /// disabled); likewise the `[host]` link shape, the `[qos]`
    /// scheduling policy and the `[engine]` execution knobs (all
    /// normalized when dormant, so dormant sections never fragment reuse —
    /// the engine knobs are in the key so a reused simulator picks up a
    /// changed `threads`/`window_ps` instead of keeping the old config).
    /// The `[observe]` section is keyed too — switching observation on or
    /// off mid-sweep must rebuild the observer state, not inherit it.
    #[allow(clippy::type_complexity)]
    pub fn reuse_key(
        cfg: &SsdConfig,
    ) -> (
        u16,
        u16,
        u32,
        u32,
        u32,
        FtlKind,
        u64,
        u32,
        u32,
        (HostLinkKind, u16),
        (SchedKind, [u32; NUM_CLASSES]),
        (u16, u64),
        (bool, bool),
        (MapMode, u64, u32),
    ) {
        let nand = cfg.nand_timing();
        let geom = Geometry {
            channels: cfg.channels,
            ways: cfg.ways,
            blocks_per_chip: cfg.blocks_per_chip,
            pages_per_block: nand.pages_per_block,
            page_bytes: nand.page_bytes,
        };
        let logical_pages = cfg.logical_pages(geom.total_pages());
        let slc_chips = cfg.tiering.slc_chips(cfg.chips());
        let migrate = if cfg.tiering.enabled {
            cfg.tiering.migrate_free_blocks
        } else {
            0
        };
        (
            cfg.channels,
            cfg.ways,
            cfg.blocks_per_chip,
            nand.pages_per_block,
            nand.page_bytes,
            cfg.ftl,
            logical_pages,
            slc_chips,
            migrate,
            cfg.host.reuse_sig(),
            cfg.qos.reuse_sig(),
            cfg.engine.reuse_sig(),
            cfg.observe.reuse_sig(),
            // The mapping tier sizes the cache directory at construction,
            // so an active section is part of the structural fingerprint;
            // a dormant one normalizes to the resident signature.
            cfg.mapping.reuse_sig(),
        )
    }

    /// Rewind this simulator to a freshly-constructed state for `cfg` over
    /// `trace`, reusing every large allocation (channel/way/chip state,
    /// FTL mapping tables, the request table, scratch buffers). The caller
    /// must have checked [`SsdSim::reuse_key`] equality; a mismatched
    /// geometry is a bug and asserts in debug builds. Behaviour after a
    /// reset is bit-identical to a freshly built simulator (tested below).
    pub fn reset(&mut self, cfg: SsdConfig, trace: &[Request]) {
        debug_assert_eq!(
            Self::reuse_key(&cfg),
            Self::reuse_key(&self.cfg),
            "reset with an incompatible geometry"
        );
        let nand = cfg.nand_timing();
        let ecc = EccModel::for_cell(cfg.cell);
        for ch in &mut self.channels {
            ch.reset(&cfg.params, cfg.iface, ecc, nand);
        }
        // Retarget the tier state: the partition is reuse-key-stable, but
        // the per-tier interfaces may change between sweep points, and the
        // SLC tier's ways need their SLC-mode timing back after the
        // uniform channel reset.
        self.slc_chips = cfg.tiering.slc_chips(cfg.chips()) as usize;
        let (slc_iface, mlc_iface) = Self::tier_ifaces(&cfg);
        self.slc_bus = BusTiming::from_params(&cfg.params, slc_iface);
        self.mlc_bus = BusTiming::from_params(&cfg.params, mlc_iface);
        if self.slc_chips > 0 {
            let slc_nand = nand.slc_mode();
            for ch in 0..cfg.channels {
                for way in 0..cfg.ways {
                    if self.geom.chip_of(ch, way) < self.slc_chips {
                        self.channels[ch as usize].ways[way as usize]
                            .chip
                            .reset(slc_nand);
                    }
                }
            }
        }
        self.bus_ctx.fill(None);
        self.ftl.reset();
        self.ftl.set_gc_tuning(cfg.steady.tuning());
        self.cache.reset(cfg.cache);
        self.trace.clear();
        self.trace.extend_from_slice(trace);
        self.arrivals.clear();
        self.streams.clear();
        self.next_req = 0;
        self.issued = 0;
        self.outstanding = 0;
        self.reqs.clear();
        self.reqs.resize_with(self.trace.len(), || None);
        self.ftl_ops.clear();
        self.kick_list.clear();
        self.map_ops.clear();
        self.map_waiters.clear();
        self.shard_outbox = None;
        self.counters = SimCounters::default();
        self.stream_class.clear();
        self.stream_requests.clear();
        self.stream_bytes.clear();
        self.stream_latency_samples.clear();
        self.latency = Welford::new();
        self.latency_samples.clear();
        self.gc_latency_samples.clear();
        self.clean_latency_samples.clear();
        self.power = if cfg.tiering.enabled {
            PowerModel::for_tiered(slc_iface, mlc_iface)
        } else {
            PowerModel::for_interface(cfg.iface)
        };
        self.energy = EnergyMeter::default();
        self.finished_at = Ps::ZERO;
        self.cfg = cfg;
        // The link shape is reuse-key-stable but its rate/overhead (and
        // the queue count's telemetry vector) may change: rebuild both the
        // link and the admission front end from the new config.
        self.link = Self::build_link(&self.cfg);
        self.rebuild_admission();
        self.rebuild_observer();
    }

    /// Run the model to completion; returns the engine statistics.
    pub fn run(&mut self) -> RunResult {
        let mut sched = Scheduler::new();
        self.run_with(&mut sched)
    }

    /// Conservative lookahead for the sharded executor: the configured
    /// `window_ps` when set, else the minimum bus phase across every
    /// channel interface in play (both tier buses when tiering splits
    /// them) — nothing crosses a channel boundary in less bus time than
    /// that, which is the window-safety bound (DESIGN.md §Engine).
    fn window_lookahead(&self) -> Ps {
        if self.cfg.engine.window_ps > 0 {
            return Ps::ps(self.cfg.engine.window_ps.min(i64::MAX as u64) as i64);
        }
        let mut la = self
            .channels
            .iter()
            .map(|c| c.bus.timing.min_phase())
            .fold(Ps::MAX, Ps::min);
        if self.slc_chips > 0 {
            la = la.min(self.slc_bus.min_phase()).min(self.mlc_bus.min_phase());
        }
        la.max(Ps::ps(1))
    }

    /// Channel-sharded execution: every channel becomes a [`ChannelShard`]
    /// advancing its own calendar over conservative windows of width
    /// [`Self::window_lookahead`], while the global state (FTL planning,
    /// GC/WL/migration, admission, cache, map-cache, host link, counters,
    /// energy) runs as the serialized commit step ([`SsdHub`]) at window
    /// boundaries. Results depend on the window width — FTL job release is
    /// quantized to window boundaries, a bounded approximation — but never
    /// on the thread count: threads 1/2/4/... produce byte-identical
    /// reports (golden-tested below and in `rust/tests/sharded_engine.rs`).
    fn run_sharded(&mut self, sched: &mut Scheduler<Ev>) -> RunResult {
        let lookahead = self.window_lookahead();
        let observe = self.cfg.observe.enabled;
        let timeline = self.cfg.observe.timeline;
        let ways = self.cfg.ways as usize;
        let wear = self.cfg.steady.enabled && self.cfg.steady.wear_level_spread > 0;
        // The whole-drive observer is replaced for this run by one
        // single-channel slice per shard; the slices are concatenated back
        // into a whole-drive report after the run.
        self.obs = None;
        let channels = std::mem::take(&mut self.channels);
        let nch = channels.len();
        let shards: Vec<ChannelShard> = channels
            .into_iter()
            .enumerate()
            .map(|(ch, chan)| {
                let obs =
                    observe.then(|| Box::new(ObsState::new(1, ways, timeline, lookahead)));
                ChannelShard::new(
                    ch as u16,
                    chan,
                    self.geom,
                    self.slc_chips,
                    self.slc_bus,
                    self.mlc_bus,
                    self.cfg.program_status_overhead,
                    wear,
                    obs,
                )
            })
            .collect();
        let mut sim = ShardedSim::new(shards, lookahead);
        // Satellite of the sharding work: `[engine] threads` beyond the
        // channel count buys nothing (one shard per channel), so clamp.
        let threads = (self.cfg.engine.threads.max(1) as usize).min(nch.max(1));
        self.shard_outbox = Some(Vec::new());
        let (mut result, hub_events) = {
            let mut hub = SsdHub {
                sim: self,
                sched,
                events: 0,
                link_busy: false,
                observe,
                nch: nch as u32,
            };
            let r = sim.run_hub(Ps::MAX, threads, &mut hub);
            (r, hub.events)
        };
        self.shard_outbox = None;
        // Move the channel state back and merge the observer slices.
        let mut slices = Vec::with_capacity(if observe { nch } else { 0 });
        let mut chans = Vec::with_capacity(nch);
        for shard in sim.into_models() {
            let (chan, obs) = shard.into_parts();
            chans.push(chan);
            if let Some(o) = obs {
                slices.push(*o);
            }
        }
        self.channels = chans;
        if !slices.is_empty() {
            self.obs = Some(Box::new(ObsState::merge_shards(slices, self.finished_at)));
        }
        result.events += hub_events;
        result
    }

    /// Like [`run`](SsdSim::run), but on a caller-provided scheduler whose
    /// calendar allocations are reused across runs (sweep workers).
    ///
    /// `[engine]` selects the execution engine: the default runs the
    /// classic single-threaded loop, byte-for-byte unchanged; any windowed
    /// setting (`threads > 1` or an explicit `window_ps`) dispatches
    /// through the channel-sharded executor ([`Self::run_sharded`]), whose
    /// results depend on the window width but not the thread count.
    pub fn run_with(&mut self, sched: &mut Scheduler<Ev>) -> RunResult {
        sched.reset();
        if self.arrivals.is_empty() {
            // Closed loop: fill the submission queues once, now that the
            // trace and stream track are both final.
            if let Some(q) = self.subq.as_mut() {
                q.prime(self.trace.len(), &self.streams);
            }
            sched.at(Ps::ZERO, Ev::Admit);
        } else {
            sched.at(self.arrivals[0], Ev::Arrive);
        }
        let result = if self.cfg.engine.windowed() {
            self.run_sharded(sched)
        } else {
            Engine::run(self, sched, Ps::MAX)
        };
        assert!(self.is_done(), "simulation drained without completing trace");
        // Close the books: controller energy over the active window.
        let window = self.finished_at;
        let power = self.power.clone();
        self.energy.add_window(&power, window);
        self.energy.add_bytes(self.counters.host_bytes);
        // Close the observer's books at the same instant as the energy
        // window; `finalize` clamps up to the last observed event, so a GC
        // drain tail past the final host completion stays counted.
        if let Some(obs) = self.obs.as_mut() {
            obs.finalize(window);
        }
        result
    }

    /// Per-channel bus utilizations at end of run.
    pub fn bus_utilizations(&self) -> Vec<f64> {
        self.channels
            .iter()
            .map(|c| c.bus.utilization(self.finished_at))
            .collect()
    }

    /// Host-link utilization at end of run (the name predates the
    /// pluggable link; it reports whichever link the config selected).
    pub fn sata_utilization(&self) -> f64 {
        self.link.utilization(self.finished_at)
    }

    /// Replace every channel's way scheduler (testing hook — the
    /// scheduler-equivalence oracle in `rust/tests/qos.rs` injects the
    /// pre-refactor arbiter verbatim and compares whole reports).
    pub fn set_way_schedulers<F: Fn() -> Box<dyn WayScheduler>>(&mut self, mk: F) {
        for ch in &mut self.channels {
            ch.set_scheduler(mk());
        }
    }

    /// FTL counters: (relocations, erases, free_pages).
    pub fn ftl_stats(&self) -> (u64, u64, u64) {
        (
            self.ftl.relocations(),
            self.ftl.erases(),
            self.ftl.free_pages(),
        )
    }

    /// Cache hit-rate over the run (0 if disabled).
    pub fn cache_hit_rate(&self) -> f64 {
        self.cache.hit_rate()
    }

    /// Dirty pages still resident in the DRAM cache at end of run — the
    /// set a power-down shutdown flush would have to write to NAND. The
    /// simulation window ends at the last host completion, so these are
    /// reported rather than flushed (conservation-tested in
    /// `rust/tests/cache_des.rs`).
    pub fn cache_dirty_pages(&self) -> Vec<u64> {
        self.cache.dirty_pages()
    }
}

impl Model for SsdSim {
    type Ev = Ev;

    fn handle(&mut self, sched: &mut Scheduler<Ev>, ev: Ev) {
        match ev {
            Ev::Admit => self.admit(sched),
            Ev::Arrive => self.arrive(sched),
            Ev::SataDone { req, phase } => match phase {
                SataPhase::HostDataIn => self.start_write_pages(req, sched),
                SataPhase::ReadCmd => self.start_read_pages(req, sched),
                SataPhase::ReadChunk => {
                    let done = {
                        let st = self.reqs[req as usize].as_mut().expect("unknown request");
                        debug_assert_eq!(st.kind, RequestKind::Read);
                        st.chunks_done += 1;
                        st.chunks_done == st.pages_total
                    };
                    if done {
                        self.complete_request(req, sched);
                    }
                }
            },
            Ev::BusDone { ch } => self.on_bus_done(ch, sched),
            Ev::ChipDone { ch, way } => self.on_chip_done(ch, way, sched),
        }
        // Occupancy scan: state is piecewise-constant between events, so
        // closing the interval after each event keeps the accounting exact
        // under both engines (they all dispatch through this method). A
        // same-timestamp batch degenerates to dt = 0 scans whose final
        // reclassification wins. One branch when observation is off.
        if self.obs.is_some() {
            self.observe_scan(sched.now());
        }
    }
}

/// The serialized commit step of a channel-sharded run: everything that is
/// *in front of* the NAND interfaces — admission, host link, cache, FTL
/// planning, mapping tier, counters, energy — replayed on the coordinating
/// thread once per window. The hub's own calendar is the ordinary
/// [`Scheduler`] (`Admit`/`Arrive`/`SataDone`; `BusDone`/`ChipDone` never
/// occur here, the shards own those), and its events are interleaved with
/// the shards' completion messages in time order, hub-first at ties — a
/// fixed rule, so the schedule is a pure function of the window width and
/// independent of thread count.
struct SsdHub<'a> {
    sim: &'a mut SsdSim,
    sched: &'a mut Scheduler<Ev>,
    /// Hub-side events dispatched (added to the run's event count).
    events: u64,
    /// Last link occupancy broadcast to the shard observers.
    link_busy: bool,
    observe: bool,
    nch: u32,
}

impl Hub<ChannelShard> for SsdHub<'_> {
    fn next_time(&mut self) -> Option<Ps> {
        self.sched.peek_next_time()
    }

    fn commit(
        &mut self,
        msgs: &[(EventKey, ShardMsg)],
        w_end: Ps,
        out: &mut HubEmit<ShardEv>,
    ) {
        let mut i = 0;
        loop {
            let hub_t = self.sched.peek_next_time().filter(|&t| t < w_end);
            let msg_t = msgs.get(i).map(|(k, _)| k.at);
            match (hub_t, msg_t) {
                (Some(ht), mt) if mt.map_or(true, |m| ht <= m) => {
                    self.sched.set_now(ht);
                    // Drain the whole same-timestamp batch, including
                    // follow-ups scheduled at `ht` by the batch itself
                    // (mirrors `Engine::run`).
                    while let Some(ev) = self.sched.pop_at(ht) {
                        self.events += 1;
                        Model::handle(&mut *self.sim, self.sched, ev);
                    }
                }
                (_, Some(mt)) => {
                    let (key, msg) = &msgs[i];
                    i += 1;
                    self.sched.set_now(mt);
                    // The emitting shard's id is the channel index.
                    let ch = key.src as u16;
                    match *msg {
                        ShardMsg::ReadOut {
                            req,
                            way,
                            block,
                            page,
                        } => self.sim.shard_read_out(ch, req, way, block, page, self.sched),
                        ShardMsg::Programmed { req } => {
                            self.sim.shard_programmed(req, self.sched)
                        }
                        ShardMsg::Erased { way, spread } => {
                            self.sim.shard_erased(ch, way, spread, self.sched)
                        }
                    }
                }
                (None, None) => break,
            }
        }
        // Release the window's planned jobs to their shards at the window
        // boundary, in plan order (hub injection keys are sequential, so
        // each shard enqueues its subset in exactly this order).
        let mut outbox = self.sim.shard_outbox.take().expect("hub commit without outbox");
        for (ch, way, job, gc_mark) in outbox.drain(..) {
            out.send_at(ch as u32, w_end, ShardEv::Enqueue { way, job, gc_mark });
        }
        self.sim.shard_outbox = Some(outbox);
        // Mirror host-link occupancy to the shard observers (stall
        // attribution only), broadcast on change at the window boundary.
        if self.observe {
            let busy = self.sim.link.busy_at(w_end);
            if busy != self.link_busy {
                self.link_busy = busy;
                for ch in 0..self.nch {
                    out.send_at(ch, w_end, ShardEv::LinkBusy(busy));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::host::trace::TraceGen;
    use crate::iface::timing::InterfaceKind;
    use crate::nand::datasheet::CellType;

    fn small_cfg(iface: InterfaceKind, ways: u16) -> SsdConfig {
        SsdConfig {
            iface,
            ways,
            blocks_per_chip: 256,
            ..SsdConfig::default()
        }
    }

    fn write_trace(n: usize) -> Vec<Request> {
        TraceGen::default()
            .sequential(RequestKind::Write, n)
            .requests
    }

    fn read_trace(n: usize) -> Vec<Request> {
        TraceGen::default()
            .sequential(RequestKind::Read, n)
            .requests
    }

    #[test]
    fn write_run_completes_and_counts() {
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, 2), write_trace(10));
        sim.run();
        assert!(sim.is_done());
        assert_eq!(sim.counters.requests_done, 10);
        assert_eq!(sim.counters.host_bytes, 10 * 65536);
        // 10 requests x 32 SLC pages.
        assert_eq!(sim.counters.pages_programmed, 320);
        assert!(sim.bandwidth_mbps() > 0.0);
    }

    #[test]
    fn read_run_completes() {
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Conv, 2), read_trace(10));
        sim.prefill_for_reads();
        sim.run();
        assert_eq!(sim.counters.requests_done, 10);
        assert_eq!(sim.counters.pages_read, 320);
    }

    #[test]
    fn proposed_beats_conv_on_reads() {
        let bw = |iface| {
            let mut sim = SsdSim::new(small_cfg(iface, 4), read_trace(50));
            sim.prefill_for_reads();
            sim.run();
            sim.bandwidth_mbps()
        };
        let conv = bw(InterfaceKind::Conv);
        let sync = bw(InterfaceKind::SyncOnly);
        let prop = bw(InterfaceKind::Proposed);
        assert!(
            prop > sync && sync > conv,
            "expected PROPOSED > SYNC_ONLY > CONV, got {prop} {sync} {conv}"
        );
    }

    #[test]
    fn way_interleaving_scales_writes() {
        let bw = |ways| {
            let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, ways), write_trace(30));
            sim.run();
            sim.bandwidth_mbps()
        };
        let w1 = bw(1);
        let w4 = bw(4);
        assert!(w4 > 3.0 * w1, "4-way should be ~4x 1-way: {w1} vs {w4}");
    }

    #[test]
    fn mlc_slower_than_slc_writes() {
        let bw = |cell| {
            let cfg = SsdConfig {
                cell,
                blocks_per_chip: 256,
                ..small_cfg(InterfaceKind::Conv, 1)
            };
            let mut sim = SsdSim::new(cfg, write_trace(10));
            sim.run();
            sim.bandwidth_mbps()
        };
        assert!(bw(CellType::Slc) > 1.5 * bw(CellType::Mlc));
    }

    #[test]
    fn latency_recorded_per_request() {
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, 1), write_trace(5));
        sim.run();
        assert_eq!(sim.latency.count(), 5);
        assert!(sim.latency.mean() > 0.0);
        assert_eq!(sim.latency_samples.len(), 5);
        let mean = sim.latency_samples.iter().sum::<f64>() / 5.0;
        assert!((mean - sim.latency.mean()).abs() < 1e-9);
    }

    /// Open loop: requests are admitted at their arrival times, and with
    /// arrivals far apart every request sees an idle device (equal
    /// latencies, end time dominated by the last arrival).
    #[test]
    fn open_loop_admits_at_arrivals() {
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, 2), write_trace(3));
        sim.set_arrivals(&[Ps::ZERO, Ps::ms(20), Ps::ms(40)]);
        sim.run();
        assert!(sim.is_done());
        assert_eq!(sim.counters.requests_done, 3);
        assert_eq!(sim.latency_samples.len(), 3);
        assert!(sim.finished_at() >= Ps::ms(40));
        let spread = sim.latency.max() - sim.latency.min();
        assert!(
            spread <= sim.latency.mean() * 0.05,
            "idle-device arrivals must see equal latency: min={} max={}",
            sim.latency.min(),
            sim.latency.max()
        );
    }

    /// Simultaneous (bursty) arrivals queue up and all complete.
    #[test]
    fn open_loop_burst_arrivals_complete() {
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, 4), write_trace(8));
        sim.set_arrivals(&[Ps::ZERO; 8]);
        sim.run();
        assert_eq!(sim.counters.requests_done, 8);
        // Later burst members wait behind earlier ones: latency spreads.
        assert!(sim.latency.max() > sim.latency.min());
    }

    /// A reset clears the arrival track: the same simulator reused for a
    /// closed-loop run is bit-identical to a fresh closed-loop simulator.
    #[test]
    fn reset_restores_closed_loop_exactly() {
        let fingerprint = |sim: &SsdSim, r: RunResult| {
            (
                r.events,
                sim.finished_at(),
                sim.counters.pages_programmed,
                sim.latency.mean(),
            )
        };
        let mut fresh = SsdSim::new(small_cfg(InterfaceKind::Proposed, 2), write_trace(10));
        let rf = fresh.run();
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, 2), write_trace(10));
        sim.set_arrivals(&[Ps::us(100); 10]);
        sim.run();
        let t = write_trace(10);
        sim.reset(small_cfg(InterfaceKind::Proposed, 2), &t);
        let rr = sim.run();
        assert_eq!(fingerprint(&sim, rr), fingerprint(&fresh, rf));
    }

    #[test]
    fn energy_accounted() {
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, 4), write_trace(10));
        sim.run();
        assert!(sim.energy.controller_nj_per_byte() > 0.0);
    }

    #[test]
    fn deterministic_repeat() {
        let run = || {
            let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, 4), write_trace(20));
            sim.run();
            (sim.finished_at(), sim.counters.pages_programmed)
        };
        assert_eq!(run(), run());
    }

    /// Golden guarantee of the sweep-reuse path: a reset-and-reused
    /// simulator must be bit-identical to a freshly constructed one —
    /// same event count, same end time, same counters, same latency stats.
    #[test]
    fn reused_simulator_bit_identical_to_fresh() {
        let fingerprint = |iface, trace: Vec<Request>| {
            let mut sim = SsdSim::new(small_cfg(iface, 4), trace);
            let r = sim.run();
            (
                r.events,
                sim.finished_at(),
                sim.counters.pages_programmed,
                sim.counters.requests_done,
                sim.latency.mean(),
                sim.bandwidth_mbps(),
                sim.energy.controller_nj_per_byte(),
            )
        };
        // Interfaces share geometry, so a worker may retarget across them.
        assert_eq!(
            SsdSim::reuse_key(&small_cfg(InterfaceKind::Conv, 4)),
            SsdSim::reuse_key(&small_cfg(InterfaceKind::Proposed, 4)),
        );
        // Dirty a simulator with a CONV run, then reuse it for PROPOSED.
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Conv, 4), write_trace(12));
        sim.run();
        let t = write_trace(20);
        sim.reset(small_cfg(InterfaceKind::Proposed, 4), &t);
        let r = sim.run();
        let reused = (
            r.events,
            sim.finished_at(),
            sim.counters.pages_programmed,
            sim.counters.requests_done,
            sim.latency.mean(),
            sim.bandwidth_mbps(),
            sim.energy.controller_nj_per_byte(),
        );
        assert_eq!(reused, fingerprint(InterfaceKind::Proposed, write_trace(20)));
    }

    /// Reuse also holds for the read path (prefill after reset) and for a
    /// reused scheduler (`run_with`).
    #[test]
    fn reused_simulator_and_scheduler_reads_identical() {
        let mut fresh = SsdSim::new(small_cfg(InterfaceKind::Proposed, 2), read_trace(10));
        fresh.prefill_for_reads();
        let rf = fresh.run();
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, 2), write_trace(7));
        let mut sched = Scheduler::new();
        sim.run_with(&mut sched);
        let t = read_trace(10);
        sim.reset(small_cfg(InterfaceKind::Proposed, 2), &t);
        sim.prefill_for_reads();
        let rr = sim.run_with(&mut sched);
        assert_eq!(rr.events, rf.events);
        assert_eq!(rr.end_time, rf.end_time);
        assert_eq!(sim.finished_at(), fresh.finished_at());
        assert_eq!(sim.counters.pages_read, fresh.counters.pages_read);
        assert_eq!(sim.latency.mean(), fresh.latency.mean());
    }

    /// Golden bit-identity of the channel-sharded executor: at a *fixed*
    /// window width, `[engine] threads` at 1/2/4 must produce byte-identical
    /// reports — same event count, end time, counters, latency, bandwidth
    /// and energy. (The window width itself is a fidelity knob — job
    /// release is quantized to window boundaries — so the sharded run is
    /// compared against its own threads-1 execution, not the classic
    /// engine; thread count must never show in the numbers.)
    #[test]
    fn windowed_engine_bit_identical_at_threads_1_2_4() {
        let fingerprint = |sim: &SsdSim, r: RunResult| {
            (
                r.events,
                sim.finished_at(),
                sim.counters.pages_programmed,
                sim.counters.pages_read,
                sim.counters.requests_done,
                sim.latency.mean(),
                sim.bandwidth_mbps(),
                sim.energy.controller_nj_per_byte(),
            )
        };
        for iface in [InterfaceKind::Conv, InterfaceKind::Proposed] {
            // Default (bus min-phase) lookahead and an explicit wide
            // window both hold the invariant.
            for window_ps in [0u64, 1_000_000] {
                let run_at = |threads: u16| {
                    let mut cfg = small_cfg(iface, 4);
                    cfg.engine.threads = threads;
                    cfg.engine.window_ps = window_ps;
                    // threads == 1 needs the explicit window to route
                    // through the sharded executor at all.
                    if threads == 1 && window_ps == 0 {
                        cfg.engine.window_ps = 1;
                    }
                    assert!(cfg.engine.windowed());
                    let mut sim = SsdSim::new(cfg, write_trace(15));
                    let r = sim.run();
                    fingerprint(&sim, r)
                };
                let golden = run_at(if window_ps == 0 { 2 } else { 1 });
                for threads in [2u16, 4] {
                    assert_eq!(
                        run_at(threads),
                        golden,
                        "iface {iface:?} window {window_ps} threads {threads}"
                    );
                }
            }
        }
        // Read path too (prefill + sharded run).
        let read_at = |threads: u16| {
            let mut cfg = small_cfg(InterfaceKind::Proposed, 2);
            cfg.engine.threads = threads;
            cfg.engine.window_ps = 500_000;
            let mut sim = SsdSim::new(cfg, read_trace(10));
            sim.prefill_for_reads();
            let r = sim.run();
            fingerprint(&sim, r)
        };
        let golden = read_at(1);
        for threads in [2u16, 4] {
            assert_eq!(read_at(threads), golden, "read path threads {threads}");
        }
    }

    /// Fresh-drive sequential fills never amplify: WAF is exactly 1 and no
    /// internal program traffic exists.
    #[test]
    fn fresh_sequential_fill_has_unit_waf() {
        let mut sim = SsdSim::new(small_cfg(InterfaceKind::Proposed, 2), write_trace(10));
        sim.run();
        assert_eq!(sim.waf(), 1.0);
        assert_eq!(sim.counters.gc_pages_programmed, 0);
        assert_eq!(sim.counters.wl_pages_programmed, 0);
        assert_eq!(sim.counters.gc_requests, 0);
        assert!(sim.gc_latency_samples.is_empty());
        assert_eq!(sim.clean_latency_samples.len(), 10);
    }

    /// Steady-state regime: preconditioned drive + rewrites at low
    /// over-provisioning force GC copy-back; WAF rises above 1 and the
    /// GC-hit requests are attributed.
    #[test]
    fn steady_rewrites_amplify_and_attribute_gc() {
        let mut cfg = small_cfg(InterfaceKind::Proposed, 2);
        cfg.blocks_per_chip = 64;
        cfg.steady.enabled = true;
        cfg.steady.over_provision = 0.07;
        // Rewrite the start of the volume repeatedly after a full fill.
        let mut trace = Vec::new();
        for round in 0..6u64 {
            for i in 0..20u64 {
                trace.push(Request {
                    kind: RequestKind::Write,
                    offset: ((round * 7 + i) % 24) * 65536,
                    bytes: 65536,
                });
            }
        }
        let n = trace.len() as u64;
        let mut sim = SsdSim::new(cfg, trace);
        sim.precondition_fill();
        sim.run();
        assert_eq!(sim.counters.requests_done, n);
        assert!(sim.waf() > 1.0, "waf={}", sim.waf());
        assert!(sim.counters.gc_pages_programmed > 0);
        assert!(sim.counters.gc_pages_read > 0);
        assert!(sim.counters.blocks_erased > 0);
        assert!(sim.counters.gc_requests > 0);
        assert_eq!(
            sim.gc_latency_samples.len() + sim.clean_latency_samples.len(),
            sim.latency_samples.len()
        );
        assert!(!sim.gc_latency_samples.is_empty());
    }

    /// The coordinator wear-leveling hook consumes `Chip::wear_spread`: with
    /// a hot/cold split that pins cold blocks, enabling the hook strictly
    /// reduces the measured end-of-run spread (and emits WL_REQ traffic).
    #[test]
    fn wear_level_hook_bounds_measured_chip_spread() {
        let run = |wl_spread: u32| {
            let mut cfg = small_cfg(InterfaceKind::Proposed, 1);
            cfg.blocks_per_chip = 64;
            cfg.steady.enabled = true;
            cfg.steady.over_provision = 0.1;
            // Isolate the coordinator hook from the FTL-internal leveler.
            cfg.steady.static_wl_threshold = u32::MAX;
            cfg.steady.wear_level_spread = wl_spread;
            let mut trace = Vec::new();
            for _ in 0..40 {
                for i in 0..8u64 {
                    trace.push(Request {
                        kind: RequestKind::Write,
                        offset: i * 65536, // hot 512 KiB; the fill stays cold
                        bytes: 65536,
                    });
                }
            }
            let mut sim = SsdSim::new(cfg, trace);
            sim.precondition_fill();
            sim.run();
            (sim.max_wear_spread(), sim.counters.wl_pages_programmed)
        };
        let (spread_off, wl_off) = run(0);
        let (spread_on, wl_on) = run(4);
        assert_eq!(wl_off, 0, "disabled hook must emit no WL traffic");
        assert!(wl_on > 0, "enabled hook must relocate cold blocks");
        assert!(
            spread_on < spread_off,
            "wear leveling must shrink the spread: {spread_on} vs {spread_off}"
        );
    }

    /// Tiered writes land in the SLC tier at SLC program latency: a small
    /// write burst on a tiered MLC drive finishes like an SLC drive, far
    /// ahead of the pure-MLC equivalent, and overflow migrates.
    #[test]
    fn tiered_writes_see_slc_latency_and_overflow_migrates() {
        let finish = |tiered: bool| {
            let mut cfg = small_cfg(InterfaceKind::Proposed, 2);
            cfg.cell = CellType::Mlc;
            cfg.blocks_per_chip = 64;
            cfg.tiering.enabled = tiered;
            cfg.tiering.slc_fraction = 0.5; // 1 of 2 chips
            let mut sim = SsdSim::new(cfg, write_trace(4));
            sim.run();
            (sim.finished_at(), sim.counters.mig_pages_programmed)
        };
        let (mlc, mig0) = finish(false);
        let (tiered, _) = finish(true);
        assert_eq!(mig0, 0);
        // Half the chips serve writes but programs run 3.9x faster: expect
        // a comfortable net win (~1.9x), assert 1.5x.
        assert!(
            tiered.as_ps() * 3 < mlc.as_ps() * 2,
            "SLC-buffered writes must finish well ahead of pure MLC: {tiered} vs {mlc}"
        );
        // Overflowing the SLC chip (64 blocks x 128 pages x 4 KiB = 32 MiB)
        // forces real migration traffic through the DES.
        let mut cfg = small_cfg(InterfaceKind::Proposed, 2);
        cfg.cell = CellType::Mlc;
        cfg.blocks_per_chip = 16; // SLC chip: 8 MiB
        cfg.tiering.enabled = true;
        cfg.tiering.slc_fraction = 0.5;
        let n = 160; // 10 MiB of 64 KiB writes
        let mut sim = SsdSim::new(cfg, write_trace(n));
        sim.run();
        assert_eq!(sim.counters.requests_done, n as u64);
        assert!(sim.counters.mig_pages_programmed > 0, "must migrate");
        assert_eq!(
            sim.counters.mig_pages_read,
            sim.counters.mig_pages_programmed
        );
        assert!(sim.waf() > 1.0, "migration is amplification: {}", sim.waf());
        assert!(sim.energy.mig_share() > 0.0);
    }

    /// Golden: a dormant `[tiering]` section perturbs nothing — the run is
    /// bit-identical to a config without one, through simulator reuse.
    #[test]
    fn tiering_disabled_bit_identical() {
        let fingerprint = |sim: &SsdSim, r: RunResult| {
            (
                r.events,
                sim.finished_at(),
                sim.counters.pages_programmed,
                sim.latency.mean(),
                sim.energy.controller_nj_per_byte(),
            )
        };
        let mut fresh = SsdSim::new(small_cfg(InterfaceKind::Proposed, 2), write_trace(10));
        let rf = fresh.run();
        let mut dormant = small_cfg(InterfaceKind::Proposed, 2);
        dormant.tiering.slc_fraction = 0.9;
        dormant.tiering.migrate_free_blocks = 9;
        assert_eq!(
            SsdSim::reuse_key(&dormant),
            SsdSim::reuse_key(&small_cfg(InterfaceKind::Proposed, 2))
        );
        let mut sim = SsdSim::new(dormant.clone(), write_trace(12));
        sim.run();
        let t = write_trace(10);
        sim.reset(dormant, &t);
        let rr = sim.run();
        assert_eq!(fingerprint(&sim, rr), fingerprint(&fresh, rf));
        assert_eq!(sim.counters.mig_pages_programmed, 0);
        assert_eq!(sim.counters.slc_reads + sim.counters.mlc_reads, 0);
    }

    /// Multi-queue closed loop: a two-stream trace completes with
    /// per-stream accounting that sums to the totals, and per-queue depth
    /// caps each stream's outstanding requests.
    #[test]
    fn multi_queue_two_streams_complete_with_accounting() {
        use crate::host::trace::{CLASS_BULK, CLASS_URGENT, StreamTag};
        let mut cfg = small_cfg(InterfaceKind::Proposed, 2);
        cfg.host.link = HostLinkKind::MultiQueue;
        cfg.host.queues = 2;
        cfg.host.queue_depth = 2;
        let trace = write_trace(12);
        let tags: Vec<StreamTag> = (0..12)
            .map(|i| StreamTag {
                stream: (i % 2) as u16,
                class: if i % 2 == 0 { CLASS_URGENT } else { CLASS_BULK },
            })
            .collect();
        let mut sim = SsdSim::new(cfg, trace);
        sim.set_streams(&tags);
        sim.run();
        assert!(sim.is_done());
        assert_eq!(sim.counters.requests_done, 12);
        assert_eq!(sim.stream_requests, vec![6, 6]);
        assert_eq!(sim.stream_bytes.iter().sum::<u64>(), sim.counters.host_bytes);
        assert_eq!(sim.stream_class, vec![CLASS_URGENT, CLASS_BULK]);
        assert_eq!(
            sim.stream_latency_samples[0].len() + sim.stream_latency_samples[1].len(),
            sim.latency_samples.len()
        );
    }

    /// Dormant `[host]`/`[qos]` sections keep the reuse fingerprint — and
    /// therefore sweep-worker retargeting — intact.
    #[test]
    fn dormant_host_qos_sections_share_reuse_key() {
        let base = small_cfg(InterfaceKind::Proposed, 2);
        let mut dormant = base.clone();
        dormant.host.queues = 64;
        dormant.host.queue_depth = 99;
        dormant.qos.weights = [1, 1, 1, 1];
        assert_eq!(SsdSim::reuse_key(&base), SsdSim::reuse_key(&dormant));
        let mut active = base.clone();
        active.qos.scheduler = crate::controller::sched::SchedKind::ReadPriority;
        assert_ne!(SsdSim::reuse_key(&base), SsdSim::reuse_key(&active));
    }

    #[test]
    fn cache_absorbs_rewrites() {
        let mut cfg = small_cfg(InterfaceKind::Conv, 1);
        cfg.cache.capacity_pages = 4096;
        // Write the same 64KB twice: second pass hits DRAM entirely.
        let mut t = write_trace(1);
        t.extend(write_trace(1));
        let mut sim = SsdSim::new(cfg, t);
        sim.run();
        assert!(sim.cache_hit_rate() > 0.4, "rate={}", sim.cache_hit_rate());
        // Only the evictions/first-pass pages reach NAND; with a big cache
        // nothing is flushed.
        assert_eq!(sim.counters.pages_programmed, 0);
        assert_eq!(sim.counters.requests_done, 2);
    }

    /// Cache write-back flushes are deferred host data: a cached run that
    /// does flush to NAND still reports zero GC counters and WAF 1.0
    /// (flush programs land on the host side of the amplification split).
    #[test]
    fn cache_flushes_are_host_attributed_not_gc() {
        let mut cfg = small_cfg(InterfaceKind::Conv, 1);
        // Tiny cache over a larger footprint: every new write evicts a
        // dirty page, so flush traffic definitely reaches NAND.
        cfg.cache.capacity_pages = 16;
        let mut sim = SsdSim::new(cfg, write_trace(8));
        sim.run();
        assert!(
            sim.counters.internal_pages > 0,
            "the tiny cache must have flushed evictions to NAND"
        );
        assert_eq!(sim.counters.gc_pages_programmed, 0);
        assert_eq!(sim.counters.wl_pages_programmed, 0);
        assert_eq!(sim.waf(), 1.0);
        assert_eq!(sim.energy.gc_share(), 0.0);
    }
}
