//! The SSD coordinator — the top-level composition that binds host, FTL,
//! cache, channels, ways and chips into one discrete-event model, plus the
//! campaign/sweep orchestration that regenerates the paper's experiments.

pub mod campaign;
pub mod experiments;
pub mod pool;
pub mod shard;
pub mod ssd;

pub use campaign::{run_trace, AccessPattern, Campaign, SimReport, StreamReport, TenantSpec};
pub use pool::ThreadPool;
pub use ssd::SsdSim;
