//! Simulation campaigns: run one configuration over a workload and collect
//! a [`SimReport`]; enumerate the paper's sweeps.

use crate::config::SsdConfig;
use crate::coordinator::ssd::SsdSim;
use crate::host::trace::{RequestKind, Trace, TraceGen};
use crate::util::time::Ps;

/// Everything measured from one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Identifying fields.
    pub iface: &'static str,
    pub cell: &'static str,
    pub channels: u16,
    pub ways: u16,
    pub mode: &'static str,
    /// Headline: host-visible bandwidth in MB/s.
    pub bandwidth_mbps: f64,
    /// Controller energy per byte in nJ/B (Table 5 metric).
    pub energy_nj_per_byte: f64,
    /// Request latency stats (µs).
    pub latency_mean_us: f64,
    pub latency_max_us: f64,
    /// Mean bus utilization across channels.
    pub bus_utilization: f64,
    pub sata_utilization: f64,
    /// Run totals.
    pub requests: u64,
    pub bytes: u64,
    pub pages_programmed: u64,
    pub pages_read: u64,
    pub blocks_erased: u64,
    pub sim_time: Ps,
    pub events: u64,
    /// Host wall-clock of the simulation itself (for perf tracking).
    pub wall_ms: f64,
}

/// Run `cfg` over an explicit trace.
pub fn run_trace(cfg: &SsdConfig, trace: &Trace) -> SimReport {
    let wall0 = std::time::Instant::now();
    let mode = match trace.requests.first().map(|r| r.kind) {
        Some(RequestKind::Read) => "read",
        _ => "write",
    };
    let mut sim = SsdSim::new(cfg.clone(), trace.requests.clone());
    let reads = trace
        .requests
        .iter()
        .any(|r| r.kind == RequestKind::Read);
    if reads {
        sim.prefill_for_reads();
    }
    let result = sim.run();
    let bus_u = {
        let us = sim.bus_utilizations();
        us.iter().sum::<f64>() / us.len().max(1) as f64
    };
    SimReport {
        iface: sim.cfg.iface.name(),
        cell: sim.cfg.cell.name(),
        channels: sim.cfg.channels,
        ways: sim.cfg.ways,
        mode,
        bandwidth_mbps: sim.bandwidth_mbps(),
        energy_nj_per_byte: sim.energy.controller_nj_per_byte(),
        latency_mean_us: sim.latency.mean(),
        latency_max_us: sim.latency.max(),
        bus_utilization: bus_u,
        sata_utilization: sim.sata_utilization(),
        requests: sim.counters.requests_done,
        bytes: sim.counters.host_bytes,
        pages_programmed: sim.counters.pages_programmed,
        pages_read: sim.counters.pages_read,
        blocks_erased: sim.counters.blocks_erased,
        sim_time: sim.finished_at(),
        events: result.events,
        wall_ms: wall0.elapsed().as_secs_f64() * 1e3,
    }
}

/// A measurement campaign: a config and a workload recipe.
#[derive(Debug, Clone)]
pub struct Campaign {
    pub cfg: SsdConfig,
    pub mode: RequestKind,
    /// Number of 64 KiB requests; clamped so the footprint fits the
    /// logical capacity (no rewrites → the paper's fresh-SSD sequential
    /// pattern never triggers GC).
    pub requests: usize,
}

impl Campaign {
    pub fn new(cfg: SsdConfig, mode: RequestKind, requests: usize) -> Campaign {
        Campaign {
            cfg,
            mode,
            requests,
        }
    }

    /// Requests that fit in 80% of logical capacity.
    fn clamped_requests(&self) -> usize {
        let nand = self.cfg.nand_timing();
        let physical = self.cfg.chips() as u64
            * self.cfg.blocks_per_chip as u64
            * nand.pages_per_block as u64
            * nand.page_bytes as u64;
        let logical = (physical as f64 * self.cfg.utilization * 0.8) as u64;
        let max_reqs = (logical / (64 * 1024)) as usize;
        self.requests.min(max_reqs.max(1))
    }

    /// Generate the workload and run.
    pub fn run(&self) -> SimReport {
        let n = self.clamped_requests();
        let trace = TraceGen::default().sequential(self.mode, n);
        run_trace(&self.cfg, &trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iface::timing::InterfaceKind;

    fn cfg() -> SsdConfig {
        SsdConfig {
            blocks_per_chip: 256,
            ..SsdConfig::default()
        }
    }

    #[test]
    fn campaign_runs_and_reports() {
        let r = Campaign::new(cfg(), RequestKind::Write, 20).run();
        assert_eq!(r.requests, 20);
        assert!(r.bandwidth_mbps > 0.0);
        assert!(r.energy_nj_per_byte > 0.0);
        assert!(r.events > 0);
        assert_eq!(r.mode, "write");
    }

    #[test]
    fn clamping_prevents_overflow() {
        // Tiny capacity: 4 blocks/chip x 64 pages x 2KiB = 512 KiB.
        let mut c = cfg();
        c.blocks_per_chip = 8;
        let camp = Campaign::new(c, RequestKind::Write, 10_000);
        let r = camp.run();
        assert!(r.requests < 10_000);
        assert!(r.requests >= 1);
    }

    #[test]
    fn read_campaign_prefills() {
        let r = Campaign::new(cfg(), RequestKind::Read, 10).run();
        assert_eq!(r.requests, 10);
        assert_eq!(r.mode, "read");
        assert!(r.pages_read >= 320);
    }

    #[test]
    fn report_identifies_config() {
        let mut c = cfg();
        c.iface = InterfaceKind::SyncOnly;
        c.channels = 1;
        c.ways = 8;
        let r = Campaign::new(c, RequestKind::Write, 5).run();
        assert_eq!(r.iface, "SYNC_ONLY");
        assert_eq!(r.ways, 8);
    }
}
