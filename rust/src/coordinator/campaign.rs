//! Simulation campaigns: run one configuration over a workload and collect
//! a [`SimReport`]; enumerate the paper's sweeps.
//!
//! [`SimWorkspace`] is the sweep-reuse vehicle: one per worker thread, it
//! keeps a simulator (channels/ways/chips/FTL tables) and a scheduler
//! (event calendar) alive across sweep points and retargets them via
//! [`SsdSim::reset`] whenever the geometry fingerprint matches, instead of
//! rebuilding everything per run (perf pass, EXPERIMENTS.md §Perf).

use crate::config::{ArrivalKind, SsdConfig};
use crate::coordinator::ssd::{Ev, SsdSim};
use crate::host::trace::{RequestKind, Trace, TraceGen};
use crate::sim::{RunResult, Scheduler};
use crate::util::stats::{jain_fairness, Summary};
use crate::util::time::{mbps, Ps};

/// Everything measured from one simulation run.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Identifying fields.
    pub iface: &'static str,
    pub cell: &'static str,
    pub channels: u16,
    pub ways: u16,
    pub mode: &'static str,
    /// Headline: host-visible bandwidth in MB/s.
    pub bandwidth_mbps: f64,
    /// Controller energy per byte in nJ/B (Table 5 metric).
    pub energy_nj_per_byte: f64,
    /// Request latency stats (µs).
    pub latency_mean_us: f64,
    pub latency_max_us: f64,
    /// Latency percentiles (µs) over the per-request samples; NaN when the
    /// run completed no requests.
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
    /// Offered load implied by the trace's arrival track, in MB/s
    /// (0 for closed-loop runs).
    pub offered_mbps: f64,
    /// Mean bus utilization across channels.
    pub bus_utilization: f64,
    pub sata_utilization: f64,
    /// Run totals.
    pub requests: u64,
    pub bytes: u64,
    pub pages_programmed: u64,
    pub pages_read: u64,
    pub blocks_erased: u64,
    pub sim_time: Ps,
    pub events: u64,
    /// Host wall-clock of the simulation itself (for perf tracking).
    pub wall_ms: f64,
    /// Steady-state accounting (EXPERIMENTS.md §Steady-State). Write
    /// amplification factor: total NAND programs / host-attributed programs
    /// (cache write-back flushes are deferred host data and count as host;
    /// exactly 1.0 on fresh-drive runs).
    pub waf: f64,
    /// GC/wear-leveling copy-back reads (subset of `pages_read`).
    pub gc_pages_read: u64,
    /// GC/merge copy-back programs (subset of `pages_programmed`).
    pub gc_pages_programmed: u64,
    /// Coordinator wear-leveling programs (subset of `pages_programmed`).
    pub wl_pages_programmed: u64,
    /// Host requests whose write plan forced GC work.
    pub gc_requests: u64,
    /// p99 latency (µs) over GC-hit requests (NaN when none occurred) and
    /// over the remaining, clean requests — the GC-attributed tail
    /// inflation pair.
    pub latency_p99_gc_us: f64,
    pub latency_p99_clean_us: f64,
    /// Largest measured per-chip P/E spread at end of run.
    pub wear_spread: u32,
    /// Fraction of NAND array energy spent on GC/WL copy-back programs.
    pub gc_energy_share: f64,
    /// Tiered-flash accounting (EXPERIMENTS.md §Tiering; all zero when the
    /// `[tiering]` section is disabled). SLC→MLC migration copy-back reads
    /// (subset of `pages_read`).
    pub mig_pages_read: u64,
    /// SLC→MLC migration programs (subset of `pages_programmed`, in the
    /// write-amplification numerator alongside GC/WL).
    pub mig_pages_programmed: u64,
    /// Host-read pages served from the SLC tier / the MLC tier.
    pub slc_reads: u64,
    pub mlc_reads: u64,
    /// Fraction of host NAND reads served by the SLC tier (NaN when the
    /// run performed no tier-attributed reads).
    pub slc_read_share: f64,
    /// Fraction of NAND array energy spent on migration programs.
    pub mig_energy_share: f64,
    /// Demand-paged mapping tier accounting (`[mapping]`,
    /// [`crate::controller::ftl::demand`]; all zero for fully-resident
    /// mapping). Map-cache hits / misses over the run.
    pub map_hits: u64,
    pub map_misses: u64,
    /// Hit fraction over all cache-consulting lookups (NaN when the
    /// mapping tier was never consulted).
    pub map_hit_rate: f64,
    /// Translation-page fill reads (subset of `pages_read`).
    pub map_pages_read: u64,
    /// Translation-page write-back programs (subset of `pages_programmed`,
    /// in the write-amplification numerator).
    pub map_pages_programmed: u64,
    /// Host page ops deferred behind a fill (demand mode only).
    pub map_deferred: u64,
    /// Mean translation stall per deferred op, µs (NaN when none deferred).
    pub map_wait_mean_us: f64,
    /// Per-stream results, indexed by stream id (empty for single-stream
    /// traces — the paper's regime costs nothing).
    pub streams: Vec<StreamReport>,
    /// Jain's fairness index over per-stream achieved throughput; NaN for
    /// fewer than two streams.
    pub fairness: f64,
    /// Bottleneck-observer results (`[observe]`, [`crate::observe`]):
    /// per-resource occupancy, stall-cause attribution and the optional
    /// trace timeline. `None` unless observation was enabled — and every
    /// other field above is bit-identical either way (the zero-perturbation
    /// contract, golden-tested in `rust/tests/observe.rs`).
    pub observe: Option<crate::observe::ObserveReport>,
}

/// Per-stream (tenant) slice of a [`SimReport`].
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub stream: u16,
    /// Priority class of the stream's requests (0 latency-critical ..
    /// 2 bulk).
    pub class: u8,
    pub requests: u64,
    pub bytes: u64,
    /// Achieved throughput over the shared run window, MB/s.
    pub bandwidth_mbps: f64,
    /// Latency stats (µs) over this stream's completions; NaN when the
    /// stream completed nothing.
    pub latency_mean_us: f64,
    pub latency_p50_us: f64,
    pub latency_p95_us: f64,
    pub latency_p99_us: f64,
}

/// Run `cfg` over an explicit trace (one-shot; sweeps should prefer a
/// per-worker [`SimWorkspace`], which reuses simulator state).
pub fn run_trace(cfg: &SsdConfig, trace: &Trace) -> SimReport {
    SimWorkspace::new().run_trace(cfg, trace)
}

fn report_from(
    sim: &mut SsdSim,
    result: RunResult,
    mode: &'static str,
    wall0: std::time::Instant,
) -> SimReport {
    let bus_u = {
        let us = sim.bus_utilizations();
        us.iter().sum::<f64>() / us.len().max(1) as f64
    };
    let (p50, p95, p99) = match Summary::from_samples(&sim.latency_samples) {
        Some(s) => (s.median, s.p95, s.p99),
        None => (f64::NAN, f64::NAN, f64::NAN),
    };
    let p99_of = |samples: &[f64]| {
        Summary::from_samples(samples)
            .map(|s| s.p99)
            .unwrap_or(f64::NAN)
    };
    // Sparse stream ids are allowed (v3 traces need not be dense): skip
    // the phantom ids nothing was tagged with — every tagged stream
    // completes at least one request by end of run, so `requests == 0`
    // identifies them — or they would surface as bogus zero-throughput
    // rows and drag the fairness index down.
    let streams: Vec<StreamReport> = (0..sim.stream_class.len())
        .filter(|&s| sim.stream_requests[s] > 0)
        .map(|s| {
            let (mean, p50, p95, p99) = match Summary::from_samples(&sim.stream_latency_samples[s])
            {
                Some(st) => (st.mean, st.median, st.p95, st.p99),
                None => (f64::NAN, f64::NAN, f64::NAN, f64::NAN),
            };
            StreamReport {
                stream: s as u16,
                class: sim.stream_class[s],
                requests: sim.stream_requests[s],
                bytes: sim.stream_bytes[s],
                bandwidth_mbps: mbps(sim.stream_bytes[s], sim.finished_at()),
                latency_mean_us: mean,
                latency_p50_us: p50,
                latency_p95_us: p95,
                latency_p99_us: p99,
            }
        })
        .collect();
    let fairness = {
        let bw: Vec<f64> = streams.iter().map(|t| t.bandwidth_mbps).collect();
        jain_fairness(&bw)
    };
    SimReport {
        iface: sim.cfg.iface.name(),
        cell: sim.cfg.cell.name(),
        channels: sim.cfg.channels,
        ways: sim.cfg.ways,
        mode,
        bandwidth_mbps: sim.bandwidth_mbps(),
        energy_nj_per_byte: sim.energy.controller_nj_per_byte(),
        latency_mean_us: sim.latency.mean(),
        latency_max_us: sim.latency.max(),
        latency_p50_us: p50,
        latency_p95_us: p95,
        latency_p99_us: p99,
        offered_mbps: 0.0,
        bus_utilization: bus_u,
        sata_utilization: sim.sata_utilization(),
        requests: sim.counters.requests_done,
        bytes: sim.counters.host_bytes,
        pages_programmed: sim.counters.pages_programmed,
        pages_read: sim.counters.pages_read,
        blocks_erased: sim.counters.blocks_erased,
        sim_time: sim.finished_at(),
        events: result.events,
        wall_ms: wall0.elapsed().as_secs_f64() * 1e3,
        waf: sim.waf(),
        gc_pages_read: sim.counters.gc_pages_read,
        gc_pages_programmed: sim.counters.gc_pages_programmed,
        wl_pages_programmed: sim.counters.wl_pages_programmed,
        gc_requests: sim.counters.gc_requests,
        latency_p99_gc_us: p99_of(&sim.gc_latency_samples),
        latency_p99_clean_us: p99_of(&sim.clean_latency_samples),
        wear_spread: sim.max_wear_spread(),
        gc_energy_share: sim.energy.gc_share(),
        mig_pages_read: sim.counters.mig_pages_read,
        mig_pages_programmed: sim.counters.mig_pages_programmed,
        slc_reads: sim.counters.slc_reads,
        mlc_reads: sim.counters.mlc_reads,
        slc_read_share: {
            let total = sim.counters.slc_reads + sim.counters.mlc_reads;
            if total == 0 {
                f64::NAN
            } else {
                sim.counters.slc_reads as f64 / total as f64
            }
        },
        mig_energy_share: sim.energy.mig_share(),
        map_hits: sim.counters.map_hits,
        map_misses: sim.counters.map_misses,
        map_hit_rate: {
            let total = sim.counters.map_hits + sim.counters.map_misses;
            if total == 0 {
                f64::NAN
            } else {
                sim.counters.map_hits as f64 / total as f64
            }
        },
        map_pages_read: sim.counters.map_pages_read,
        map_pages_programmed: sim.counters.map_pages_programmed,
        map_deferred: sim.counters.map_deferred,
        map_wait_mean_us: {
            if sim.counters.map_deferred == 0 {
                f64::NAN
            } else {
                sim.counters.map_wait_ps as f64
                    / sim.counters.map_deferred as f64
                    / 1_000_000.0
            }
        },
        streams,
        fairness,
        observe: sim.take_observe_report(),
    }
}

/// Reusable per-worker simulation state (see the module docs).
pub struct SimWorkspace {
    sim: Option<SsdSim>,
    sched: Scheduler<Ev>,
    /// Runs served by resetting the cached simulator (telemetry for the
    /// perf harness).
    pub reuses: u64,
    /// Runs that had to build a fresh simulator.
    pub builds: u64,
}

impl Default for SimWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl SimWorkspace {
    pub fn new() -> SimWorkspace {
        SimWorkspace {
            sim: None,
            sched: Scheduler::new(),
            reuses: 0,
            builds: 0,
        }
    }

    /// Run `cfg` over `trace`, retargeting this worker's cached simulator
    /// when the geometry fingerprint matches ([`SsdSim::reuse_key`]).
    /// Results are bit-identical to a fresh build either way.
    pub fn run_trace(&mut self, cfg: &SsdConfig, trace: &Trace) -> SimReport {
        // simlint: allow(nondet, "wall-clock sweep duration for PerfLog reporting, not sim time")
        let wall0 = std::time::Instant::now();
        let mode = match trace.requests.first().map(|r| r.kind) {
            Some(RequestKind::Read) => "read",
            _ => "write",
        };
        let reusable = self
            .sim
            .as_ref()
            .is_some_and(|s| SsdSim::reuse_key(&s.cfg) == SsdSim::reuse_key(cfg));
        if reusable {
            self.reuses += 1;
            self.sim
                .as_mut()
                .expect("reusable implies cached sim")
                .reset(cfg.clone(), &trace.requests);
        } else {
            self.builds += 1;
            self.sim = Some(SsdSim::new(cfg.clone(), trace.requests.clone()));
        }
        let sim = self.sim.as_mut().expect("just placed");
        sim.set_arrivals(&trace.arrivals);
        sim.set_streams(&trace.streams);
        if cfg.steady.enabled && cfg.steady.precondition {
            sim.precondition_fill();
        }
        if trace.requests.iter().any(|r| r.kind == RequestKind::Read) {
            sim.prefill_for_reads();
        }
        let result = sim.run_with(&mut self.sched);
        let mut rep = report_from(sim, result, mode, wall0);
        rep.offered_mbps = trace.offered_mbps().unwrap_or(0.0);
        rep
    }
}

/// Access pattern of one tenant in a multi-tenant campaign.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AccessPattern {
    /// Back-to-back extents from the start of the tenant's volume slice.
    Sequential,
    /// Uniform-random aligned offsets within the tenant's slice.
    Random,
}

/// One tenant (stream) of a multi-tenant campaign: its workload shape,
/// priority class, and — when every tenant carries one — its own offered
/// load stamped as a Poisson arrival track before the streams merge.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub mode: RequestKind,
    pub pattern: AccessPattern,
    /// Priority class (0 latency-critical ..= 2 bulk).
    pub class: u8,
    /// Number of 64 KiB requests this tenant issues.
    pub requests: usize,
    /// Per-tenant offered load (MB/s) for open-loop arrival stamping;
    /// `None` = closed loop. All tenants of one campaign must agree on
    /// which regime they run.
    pub offered_mbps: Option<f64>,
}

/// A measurement campaign: a config and a workload recipe.
#[derive(Debug, Clone)]
pub struct Campaign {
    pub cfg: SsdConfig,
    pub mode: RequestKind,
    /// Number of 64 KiB requests; clamped so the footprint fits the
    /// logical capacity (no rewrites → the paper's fresh-SSD sequential
    /// pattern never triggers GC).
    pub requests: usize,
    /// Per-stream workload mix. Empty = the classic single-stream
    /// campaign above; otherwise tenant `i` becomes stream `i`, each over
    /// its own disjoint slice of the logical volume (so tenants contend
    /// for channels/ways/GC, not for logical pages), merged per
    /// [`Trace::merge_streams`].
    pub tenants: Vec<TenantSpec>,
}

impl Campaign {
    pub fn new(cfg: SsdConfig, mode: RequestKind, requests: usize) -> Campaign {
        Campaign {
            cfg,
            mode,
            requests,
            tenants: Vec::new(),
        }
    }

    /// A multi-tenant campaign (`mode`/`requests` are carried by the
    /// tenant specs).
    pub fn multi_tenant(cfg: SsdConfig, tenants: Vec<TenantSpec>) -> Campaign {
        assert!(!tenants.is_empty(), "need at least one tenant");
        Campaign {
            cfg,
            mode: RequestKind::Write,
            requests: 0,
            tenants,
        }
    }

    /// Physical page count implied by the config's geometry (shared by the
    /// clamping and the steady trace-volume arithmetic so the two can
    /// never disagree).
    fn physical_pages(&self) -> u64 {
        let nand = self.cfg.nand_timing();
        self.cfg.chips() as u64 * self.cfg.blocks_per_chip as u64 * nand.pages_per_block as u64
    }

    /// Requests that fit in 80% of logical capacity.
    fn clamped_requests(&self) -> usize {
        let nand = self.cfg.nand_timing();
        let physical = self.physical_pages() * nand.page_bytes as u64;
        let logical = (physical as f64 * self.cfg.utilization * 0.8) as u64;
        let max_reqs = (logical / (64 * 1024)) as usize;
        self.requests.min(max_reqs.max(1))
    }

    /// Generate the workload and run.
    pub fn run(&self) -> SimReport {
        self.run_in(&mut SimWorkspace::new())
    }

    /// Generate the workload and run inside a reusable worker workspace.
    /// When the config's `[load]` section sets an offered load, the trace
    /// is stamped with the corresponding arrival track and the run is
    /// open loop (EXPERIMENTS.md §Load). When the `[steady]` section is
    /// enabled, the workload switches from the paper's fresh-drive
    /// sequential pattern to uniform-random requests over the full logical
    /// volume — with the preconditioning fill, every write invalidates an
    /// old page and GC runs in its sustained regime (§Steady-State); the
    /// request count is not clamped, since wrap-around rewrites are the
    /// point.
    pub fn run_in(&self, ws: &mut SimWorkspace) -> SimReport {
        if !self.tenants.is_empty() {
            return self.run_tenants(ws);
        }
        let gen = TraceGen::default();
        let mut trace = if self.cfg.steady.enabled {
            let nand = self.cfg.nand_timing();
            let volume = self.cfg.logical_pages(self.physical_pages())
                * nand.page_bytes as u64;
            gen.random(self.mode, self.requests, volume, self.cfg.seed)
        } else {
            gen.sequential(self.mode, self.clamped_requests())
        };
        if let Some(offered) = self.cfg.load.offered_mbps {
            trace = match self.cfg.load.arrival {
                ArrivalKind::Poisson => gen.poisson_arrivals(trace, offered, self.cfg.seed),
                ArrivalKind::Bursty => gen.bursty_arrivals(
                    trace,
                    offered,
                    self.cfg.load.burst as usize,
                    self.cfg.seed,
                ),
            };
        }
        let mut rep = ws.run_trace(&self.cfg, &trace);
        if let Some(offered) = self.cfg.load.offered_mbps {
            // Report the configured offered load, which stays meaningful
            // even when the arrival span degenerates (e.g. one burst).
            rep.offered_mbps = offered;
        }
        rep
    }

    /// Multi-tenant run: generate each tenant's trace over its own slice
    /// of the logical volume (sequential tenants clamped to 80% of the
    /// slice, like single-stream campaigns), stamp per-tenant Poisson
    /// arrivals when every tenant has an offered load, merge the streams
    /// and run. All tenants must agree on open vs closed loop.
    fn run_tenants(&self, ws: &mut SimWorkspace) -> SimReport {
        let gen = TraceGen::default();
        let nand = self.cfg.nand_timing();
        let volume = self.cfg.logical_pages(self.physical_pages()) * nand.page_bytes as u64;
        let n = self.tenants.len() as u64;
        let req_bytes = gen.request_bytes as u64;
        // Request-aligned slice per tenant; every tenant must fit at
        // least one request inside the logical volume, or later tenants'
        // offsets would land past the exported space.
        let slots = volume / req_bytes;
        assert!(
            slots >= n,
            "logical volume ({slots} request-sized slots) too small for {n} tenants"
        );
        let slice = (slots / n) * req_bytes;
        let open = self.tenants[0].offered_mbps.is_some();
        assert!(
            self.tenants
                .iter()
                .all(|t| t.offered_mbps.is_some() == open),
            "all tenants must agree on open vs closed loop"
        );
        let parts: Vec<(Trace, u8)> = self
            .tenants
            .iter()
            .enumerate()
            .map(|(i, t)| {
                let seed = self.cfg.seed.wrapping_add(0x9E37_79B9 * (i as u64 + 1));
                let mut tr = match t.pattern {
                    AccessPattern::Sequential => {
                        let cap = ((slice * 8 / 10) / req_bytes).max(1) as usize;
                        gen.sequential(t.mode, t.requests.min(cap))
                    }
                    AccessPattern::Random => gen.random(t.mode, t.requests, slice, seed),
                };
                let base = slice * i as u64;
                for r in &mut tr.requests {
                    r.offset += base;
                }
                if let Some(offered) = t.offered_mbps {
                    tr = gen.poisson_arrivals(tr, offered, seed);
                }
                (tr, t.class)
            })
            .collect();
        let trace = Trace::merge_streams(&parts).expect("tenant parts agree by construction");
        ws.run_trace(&self.cfg, &trace)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::iface::timing::InterfaceKind;

    fn cfg() -> SsdConfig {
        SsdConfig {
            blocks_per_chip: 256,
            ..SsdConfig::default()
        }
    }

    #[test]
    fn campaign_runs_and_reports() {
        let r = Campaign::new(cfg(), RequestKind::Write, 20).run();
        assert_eq!(r.requests, 20);
        assert!(r.bandwidth_mbps > 0.0);
        assert!(r.energy_nj_per_byte > 0.0);
        assert!(r.events > 0);
        assert_eq!(r.mode, "write");
    }

    #[test]
    fn closed_loop_report_has_percentiles_and_no_offered_load() {
        let r = Campaign::new(cfg(), RequestKind::Write, 10).run();
        assert_eq!(r.offered_mbps, 0.0);
        assert!(r.latency_p50_us.is_finite() && r.latency_p50_us > 0.0);
        assert!(r.latency_p50_us <= r.latency_p95_us);
        assert!(r.latency_p95_us <= r.latency_p99_us);
        assert!(r.latency_p99_us <= r.latency_max_us + 1e-9);
    }

    /// The `[load]` config knobs turn a campaign open loop end to end.
    #[test]
    fn load_config_drives_open_loop_campaign() {
        let mut c = cfg();
        c.load.offered_mbps = Some(5.0);
        let r = Campaign::new(c, RequestKind::Write, 30).run();
        assert_eq!(r.requests, 30);
        assert!(r.offered_mbps > 0.0, "open-loop run must report offered load");
        assert!(r.latency_p50_us > 0.0);
        let mut c2 = cfg();
        c2.load.offered_mbps = Some(5.0);
        c2.load.arrival = crate::config::ArrivalKind::Bursty;
        c2.load.burst = 4;
        let r2 = Campaign::new(c2, RequestKind::Write, 30).run();
        assert_eq!(r2.requests, 30);
        // Bursts queue behind each other: tail latency exceeds Poisson's
        // at the same (light) offered load.
        assert!(r2.latency_p99_us > r.latency_p50_us);
    }

    /// The `[steady]` section turns a campaign into a preconditioned
    /// sustained-random-write run end to end: WAF climbs above 1 and the
    /// GC columns populate.
    #[test]
    fn steady_campaign_reports_amplification() {
        let mut c = cfg();
        c.blocks_per_chip = 64;
        c.ways = 2;
        c.steady.enabled = true;
        c.steady.over_provision = 0.07;
        let r = Campaign::new(c, RequestKind::Write, 150).run();
        assert_eq!(r.requests, 150, "steady campaigns are not clamped");
        assert!(r.waf > 1.0, "waf={}", r.waf);
        assert!(r.gc_pages_programmed > 0);
        assert!(r.blocks_erased > 0);
        assert!(r.gc_requests > 0);
        assert!(r.latency_p99_gc_us.is_finite());
        assert!(r.gc_energy_share > 0.0 && r.gc_energy_share < 1.0);
        // A fresh-drive campaign of the same shape stays amplification-free.
        let clean = Campaign::new(cfg(), RequestKind::Write, 20).run();
        assert_eq!(clean.waf, 1.0);
        assert_eq!(clean.gc_pages_programmed, 0);
        assert!(clean.latency_p99_gc_us.is_nan());
    }

    /// A two-tenant campaign reports per-stream latency/throughput plus a
    /// fairness index, and the per-stream totals add up to the run totals.
    #[test]
    fn multi_tenant_campaign_reports_per_stream() {
        use crate::host::trace::{CLASS_BULK, CLASS_URGENT};
        let tenants = vec![
            TenantSpec {
                mode: RequestKind::Read,
                pattern: AccessPattern::Random,
                class: CLASS_URGENT,
                requests: 10,
                offered_mbps: Some(8.0),
            },
            TenantSpec {
                mode: RequestKind::Write,
                pattern: AccessPattern::Sequential,
                class: CLASS_BULK,
                requests: 20,
                offered_mbps: Some(20.0),
            },
        ];
        let r = Campaign::multi_tenant(cfg(), tenants).run();
        assert_eq!(r.requests, 30);
        assert_eq!(r.streams.len(), 2);
        assert_eq!(r.streams[0].class, CLASS_URGENT);
        assert_eq!(r.streams[1].class, CLASS_BULK);
        assert_eq!(r.streams[0].requests, 10);
        assert_eq!(r.streams[1].requests, 20);
        assert_eq!(
            r.streams.iter().map(|s| s.bytes).sum::<u64>(),
            r.bytes,
            "stream bytes partition the total"
        );
        assert!(r.streams.iter().all(|s| s.latency_p99_us > 0.0));
        assert!(r.fairness > 0.0 && r.fairness <= 1.0 + 1e-12);
        // Closed-loop tenant mixes work too (round-robin interleave).
        let closed = vec![
            TenantSpec {
                mode: RequestKind::Write,
                pattern: AccessPattern::Sequential,
                class: CLASS_URGENT,
                requests: 6,
                offered_mbps: None,
            },
            TenantSpec {
                mode: RequestKind::Write,
                pattern: AccessPattern::Sequential,
                class: CLASS_BULK,
                requests: 6,
                offered_mbps: None,
            },
        ];
        let rc = Campaign::multi_tenant(cfg(), closed).run();
        assert_eq!(rc.requests, 12);
        assert_eq!(rc.streams.len(), 2);
        // Single-stream campaigns stay stream-free (nothing to pay).
        let single = Campaign::new(cfg(), RequestKind::Write, 5).run();
        assert!(single.streams.is_empty());
        assert!(single.fairness.is_nan());
    }

    #[test]
    fn clamping_prevents_overflow() {
        // Tiny capacity: 4 blocks/chip x 64 pages x 2KiB = 512 KiB.
        let mut c = cfg();
        c.blocks_per_chip = 8;
        let camp = Campaign::new(c, RequestKind::Write, 10_000);
        let r = camp.run();
        assert!(r.requests < 10_000);
        assert!(r.requests >= 1);
    }

    #[test]
    fn read_campaign_prefills() {
        let r = Campaign::new(cfg(), RequestKind::Read, 10).run();
        assert_eq!(r.requests, 10);
        assert_eq!(r.mode, "read");
        assert!(r.pages_read >= 320);
    }

    /// A shared workspace across heterogeneous campaigns must reproduce
    /// the per-campaign fresh results exactly, while actually reusing the
    /// simulator for geometry-compatible consecutive points.
    #[test]
    fn workspace_reuse_matches_fresh_runs() {
        use crate::nand::datasheet::CellType;
        let points = [
            (InterfaceKind::Conv, CellType::Slc, 4u16, RequestKind::Write),
            (InterfaceKind::Proposed, CellType::Slc, 4, RequestKind::Write),
            (InterfaceKind::Proposed, CellType::Slc, 4, RequestKind::Read),
            (InterfaceKind::Proposed, CellType::Mlc, 2, RequestKind::Write),
            (InterfaceKind::SyncOnly, CellType::Mlc, 2, RequestKind::Write),
        ];
        let campaign = |(iface, cell, ways, mode): (InterfaceKind, CellType, u16, RequestKind)| {
            let c = SsdConfig {
                iface,
                cell,
                ways,
                ..cfg()
            };
            Campaign::new(c, mode, 15)
        };
        let mut ws = SimWorkspace::new();
        for p in points {
            let shared = campaign(p).run_in(&mut ws);
            let fresh = campaign(p).run();
            assert_eq!(shared.events, fresh.events, "{p:?}");
            assert_eq!(shared.sim_time, fresh.sim_time, "{p:?}");
            assert_eq!(shared.bandwidth_mbps, fresh.bandwidth_mbps, "{p:?}");
            assert_eq!(shared.energy_nj_per_byte, fresh.energy_nj_per_byte, "{p:?}");
            assert_eq!(shared.pages_programmed, fresh.pages_programmed, "{p:?}");
            assert_eq!(shared.pages_read, fresh.pages_read, "{p:?}");
        }
        // CONV→PROPOSED (same geometry) and write→read reuse; the MLC
        // switch (different page geometry) rebuilds.
        assert!(ws.reuses >= 3, "reuses={}", ws.reuses);
        assert!(ws.builds >= 2, "builds={}", ws.builds);
    }

    #[test]
    fn report_identifies_config() {
        let mut c = cfg();
        c.iface = InterfaceKind::SyncOnly;
        c.channels = 1;
        c.ways = 8;
        let r = Campaign::new(c, RequestKind::Write, 5).run();
        assert_eq!(r.iface, "SYNC_ONLY");
        assert_eq!(r.ways, 8);
    }
}
