//! The paper's experiments as reusable drivers — shared by the CLI
//! (`ddrnand paper`, `sweep-ways`, …) and the bench targets
//! (`cargo bench --bench bench_fig8_table3`, …).
//!
//! Each driver runs the DES over the same grid as the paper's table and
//! returns rows paired with the paper's published values so callers can
//! print paper-vs-measured deltas (EXPERIMENTS.md is generated from these).

use crate::analytic::paper;
use crate::config::SsdConfig;
use crate::coordinator::campaign::{Campaign, SimReport, SimWorkspace};
use crate::coordinator::pool::ThreadPool;
use crate::host::trace::RequestKind;
use crate::iface::timing::{IfaceParams, InterfaceKind};
use crate::nand::datasheet::CellType;
use crate::report::Table;

/// Default request count per cell: long enough that ramp-up is <1%.
pub const DEFAULT_REQUESTS: usize = 400;

/// One measured cell with its paper reference.
#[derive(Debug, Clone)]
pub struct Cell {
    pub cell: CellType,
    pub mode: RequestKind,
    pub channels: u16,
    pub ways: u16,
    pub iface: InterfaceKind,
    pub report: SimReport,
    /// Paper value (MB/s for Tables 3/4, nJ/B for Table 5); None = "max".
    pub paper: Option<f64>,
}

impl Cell {
    pub fn delta_pct(&self) -> Option<f64> {
        self.paper
            .map(|p| (self.measured() - p) / p * 100.0)
    }
    /// The measured quantity this cell compares (bandwidth or energy).
    pub fn measured(&self) -> f64 {
        self.report.bandwidth_mbps
    }
}

fn cfg(iface: InterfaceKind, cell: CellType, channels: u16, ways: u16) -> SsdConfig {
    SsdConfig {
        iface,
        cell,
        channels,
        ways,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    }
}

/// E1 — §5.2 / Table 2: operating-frequency determination text.
pub fn table2_text() -> String {
    let p = IfaceParams::default();
    let mut t = Table::new(vec!["interface", "t_P,min (ns)", "paper (ns)", "freq (MHz)", "paper (MHz)"]);
    let rows = [
        (InterfaceKind::Conv, 19.81, 50),
        (InterfaceKind::SyncOnly, 12.0, 83),
        (InterfaceKind::Proposed, 12.0, 83),
    ];
    for (k, paper_tp, paper_f) in rows {
        t.row(vec![
            k.name().to_string(),
            format!("{:.2}", p.tp_min_ns(k)),
            format!("{paper_tp:.2}"),
            format!("{}", p.operating_freq_mhz(k)),
            format!("{paper_f}"),
        ]);
    }
    format!(
        "E1 / Table 2 + §5.2 — operating frequency determination\n\
         (Eq. 6: CONV = max{{(t_OUT+t_REA+t_IN+t_S)/(1+α), t_BYTE}}; \
         Eq. 9: PROPOSED = max{{2(t_S+t_H+t_DIFF), t_BYTE}})\n\n{}",
        t.render()
    )
}

/// E2 — Fig. 8 / Table 3: single-channel way-interleaving sweep.
pub fn run_table3(requests: usize, pool: &ThreadPool) -> Vec<Cell> {
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for (cell, mode, rows) in paper::TABLE3 {
        for (wi, &ways) in paper::WAYS.iter().enumerate() {
            for (ii, iface) in InterfaceKind::ALL.iter().enumerate() {
                let c = cfg(*iface, cell, 1, ways);
                meta.push((cell, mode, 1u16, ways, *iface, Some(rows[wi][ii])));
                jobs.push(move |ws: &mut SimWorkspace| Campaign::new(c, mode, requests).run_in(ws));
            }
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((cell, mode, channels, ways, iface, paper), report)| Cell {
            cell,
            mode,
            channels,
            ways,
            iface,
            report,
            paper,
        })
        .collect()
}

/// E3 — Fig. 9 / Table 4: constant-capacity channel/way sweep.
pub fn run_table4(requests: usize, pool: &ThreadPool) -> Vec<Cell> {
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for (cell, mode, rows) in paper::TABLE4 {
        for (ci, &(channels, ways)) in paper::CHANNEL_CONFIGS.iter().enumerate() {
            for (ii, iface) in InterfaceKind::ALL.iter().enumerate() {
                let c = cfg(*iface, cell, channels, ways);
                meta.push((cell, mode, channels, ways, *iface, rows[ci][ii]));
                jobs.push(move |ws: &mut SimWorkspace| Campaign::new(c, mode, requests).run_in(ws));
            }
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((cell, mode, channels, ways, iface, paper), report)| Cell {
            cell,
            mode,
            channels,
            ways,
            iface,
            report,
            paper,
        })
        .collect()
}

/// E4 — Fig. 10 / Table 5: SLC energy per byte. Reuses the Table 3 SLC
/// runs; the measured quantity is nJ/B.
pub fn run_table5(requests: usize, pool: &ThreadPool) -> Vec<Cell> {
    let mut cells = run_table3(requests, pool);
    cells.retain(|c| c.cell == CellType::Slc);
    // Swap the paper reference for the energy table.
    for c in &mut cells {
        let (_, rows) = paper::TABLE5
            .iter()
            .find(|(m, _)| *m == c.mode)
            .expect("mode in table5");
        let wi = paper::WAYS.iter().position(|&w| w == c.ways).unwrap();
        c.paper = Some(rows[wi][paper::iface_index(c.iface)]);
    }
    cells
}

/// Render a table of cells; `energy` selects the nJ/B column.
pub fn render_cells(title: &str, cells: &[Cell], energy: bool) -> String {
    let mut t = Table::new(vec![
        "cell", "mode", "ch", "ways", "iface", "measured", "paper", "delta",
    ]);
    for c in cells {
        let measured = if energy {
            c.report.energy_nj_per_byte
        } else {
            c.report.bandwidth_mbps
        };
        let (paper_s, delta_s) = match c.paper {
            Some(p) => (
                format!("{p:.2}"),
                format!("{:+.1}%", (measured - p) / p * 100.0),
            ),
            None => ("max".to_string(), "-".to_string()),
        };
        t.row(vec![
            c.cell.name().to_string(),
            c.mode.name().to_string(),
            c.channels.to_string(),
            c.ways.to_string(),
            c.iface.name().to_string(),
            format!("{measured:.2}"),
            paper_s,
            delta_s,
        ]);
    }
    format!("{title}\n\n{}", t.render())
}

/// E5 — §6 headline: min/max PROPOSED/CONV ratios from Table 3 cells.
pub fn headline(cells: &[Cell]) -> String {
    let mut out = String::from("E5 / §6 headline — PROPOSED/CONV ratio ranges (paper: SLC read 1.65–2.76x, write 1.09–2.45x; MLC read 1.64–2.66x, write 1.05–1.76x)\n\n");
    for cell in [CellType::Slc, CellType::Mlc] {
        for mode in [RequestKind::Read, RequestKind::Write] {
            let mut ratios = Vec::new();
            for &w in &paper::WAYS {
                let find = |iface| {
                    cells
                        .iter()
                        .find(|c| {
                            c.cell == cell && c.mode == mode && c.ways == w && c.iface == iface
                        })
                        .map(|c| c.report.bandwidth_mbps)
                };
                if let (Some(p), Some(conv)) =
                    (find(InterfaceKind::Proposed), find(InterfaceKind::Conv))
                {
                    ratios.push(p / conv);
                }
            }
            if !ratios.is_empty() {
                let lo = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = ratios.iter().cloned().fold(0.0f64, f64::max);
                out.push_str(&format!(
                    "  {cell} {:<5}: {lo:.2}x – {hi:.2}x\n",
                    mode.name()
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_text_contains_paper_values() {
        let t = table2_text();
        assert!(t.contains("19.81"));
        assert!(t.contains("83"));
    }

    #[test]
    fn table3_grid_shape() {
        let pool = ThreadPool::new(0);
        let cells = run_table3(30, &pool);
        assert_eq!(cells.len(), 4 * 5 * 3); // 4 (cell,mode) x 5 ways x 3 ifaces
        assert!(cells.iter().all(|c| c.report.bandwidth_mbps > 0.0));
        let rendered = render_cells("t3", &cells, false);
        assert!(rendered.contains("PROPOSED"));
    }

    #[test]
    fn table5_reuses_slc_and_swaps_reference() {
        let pool = ThreadPool::new(0);
        let cells = run_table5(30, &pool);
        assert_eq!(cells.len(), 2 * 5 * 3);
        assert!(cells.iter().all(|c| c.cell == CellType::Slc));
        // 16-way write PROPOSED paper value is 0.48 nJ/B.
        let c = cells
            .iter()
            .find(|c| c.ways == 16 && c.iface == InterfaceKind::Proposed && c.mode == RequestKind::Write)
            .unwrap();
        assert_eq!(c.paper, Some(0.48));
    }

    #[test]
    fn headline_mentions_all_cells() {
        let pool = ThreadPool::new(0);
        let cells = run_table3(30, &pool);
        let h = headline(&cells);
        assert!(h.contains("SLC read"));
        assert!(h.contains("MLC write"));
    }
}
