//! The experiments as reusable drivers (E1–E5 from the paper, E6 open-loop
//! load, E7 steady-state, E8 tiered SLC/MLC, E9 multi-tenant QoS, E10
//! bottleneck observation, E11 demand-paged mapping) — shared by the CLI
//! (`ddrnand paper`, `sweep-ways`, `sweep-load`, `sweep-steady`,
//! `analyze`, …) and the bench targets (`cargo bench --bench
//! bench_fig8_table3`, …).
//!
//! Each driver runs the DES over the same grid as the paper's table and
//! returns rows paired with the paper's published values so callers can
//! print paper-vs-measured deltas (EXPERIMENTS.md is generated from these).

use crate::analytic::paper;
use crate::config::{ArrivalKind, EngineConfig, MapMode, SsdConfig};
use crate::controller::sched::SchedKind;
use crate::coordinator::campaign::{AccessPattern, Campaign, SimReport, SimWorkspace, TenantSpec};
use crate::coordinator::pool::ThreadPool;
use crate::host::link::HostLinkKind;
use crate::host::trace::{CLASS_BULK, CLASS_URGENT, RequestKind, TraceGen};
use crate::iface::timing::{IfaceParams, InterfaceKind};
use crate::nand::datasheet::CellType;
use crate::report::Table;

/// Default request count per cell: long enough that ramp-up is <1%.
pub const DEFAULT_REQUESTS: usize = 400;

/// One measured cell with its paper reference.
#[derive(Debug, Clone)]
pub struct Cell {
    pub cell: CellType,
    pub mode: RequestKind,
    pub channels: u16,
    pub ways: u16,
    pub iface: InterfaceKind,
    pub report: SimReport,
    /// Paper value (MB/s for Tables 3/4, nJ/B for Table 5); None = "max".
    pub paper: Option<f64>,
}

impl Cell {
    pub fn delta_pct(&self) -> Option<f64> {
        self.paper
            .map(|p| (self.measured() - p) / p * 100.0)
    }
    /// The measured quantity this cell compares (bandwidth or energy).
    pub fn measured(&self) -> f64 {
        self.report.bandwidth_mbps
    }
}

fn cfg(iface: InterfaceKind, cell: CellType, channels: u16, ways: u16) -> SsdConfig {
    SsdConfig {
        iface,
        cell,
        channels,
        ways,
        blocks_per_chip: 512,
        ..SsdConfig::default()
    }
}

/// E1 — §5.2 / Table 2: operating-frequency determination text.
pub fn table2_text() -> String {
    let p = IfaceParams::default();
    let mut t = Table::new(vec!["interface", "t_P,min (ns)", "paper (ns)", "freq (MHz)", "paper (MHz)"]);
    let rows = [
        (InterfaceKind::Conv, 19.81, 50),
        (InterfaceKind::SyncOnly, 12.0, 83),
        (InterfaceKind::Proposed, 12.0, 83),
    ];
    for (k, paper_tp, paper_f) in rows {
        t.row(vec![
            k.name().to_string(),
            format!("{:.2}", p.tp_min_ns(k)),
            format!("{paper_tp:.2}"),
            format!("{}", p.operating_freq_mhz(k)),
            format!("{paper_f}"),
        ]);
    }
    format!(
        "E1 / Table 2 + §5.2 — operating frequency determination\n\
         (Eq. 6: CONV = max{{(t_OUT+t_REA+t_IN+t_S)/(1+α), t_BYTE}}; \
         Eq. 9: PROPOSED = max{{2(t_S+t_H+t_DIFF), t_BYTE}})\n\n{}",
        t.render()
    )
}

/// E2 — Fig. 8 / Table 3: single-channel way-interleaving sweep.
pub fn run_table3(requests: usize, pool: &ThreadPool) -> Vec<Cell> {
    run_table3_with(requests, pool, EngineConfig::default())
}

/// [`run_table3`] with an explicit per-sim engine configuration
/// (`--threads` on the CLI; the sweep-level parallelism knob is the pool).
pub fn run_table3_with(requests: usize, pool: &ThreadPool, engine: EngineConfig) -> Vec<Cell> {
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for (cell, mode, rows) in paper::TABLE3 {
        for (wi, &ways) in paper::WAYS.iter().enumerate() {
            for (ii, iface) in InterfaceKind::ALL.iter().enumerate() {
                let mut c = cfg(*iface, cell, 1, ways);
                c.engine = engine;
                meta.push((cell, mode, 1u16, ways, *iface, Some(rows[wi][ii])));
                jobs.push(move |ws: &mut SimWorkspace| Campaign::new(c, mode, requests).run_in(ws));
            }
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((cell, mode, channels, ways, iface, paper), report)| Cell {
            cell,
            mode,
            channels,
            ways,
            iface,
            report,
            paper,
        })
        .collect()
}

/// E3 — Fig. 9 / Table 4: constant-capacity channel/way sweep.
pub fn run_table4(requests: usize, pool: &ThreadPool) -> Vec<Cell> {
    run_table4_with(requests, pool, EngineConfig::default())
}

/// [`run_table4`] with an explicit per-sim engine configuration.
pub fn run_table4_with(requests: usize, pool: &ThreadPool, engine: EngineConfig) -> Vec<Cell> {
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for (cell, mode, rows) in paper::TABLE4 {
        for (ci, &(channels, ways)) in paper::CHANNEL_CONFIGS.iter().enumerate() {
            for (ii, iface) in InterfaceKind::ALL.iter().enumerate() {
                let mut c = cfg(*iface, cell, channels, ways);
                c.engine = engine;
                meta.push((cell, mode, channels, ways, *iface, rows[ci][ii]));
                jobs.push(move |ws: &mut SimWorkspace| Campaign::new(c, mode, requests).run_in(ws));
            }
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((cell, mode, channels, ways, iface, paper), report)| Cell {
            cell,
            mode,
            channels,
            ways,
            iface,
            report,
            paper,
        })
        .collect()
}

/// E4 — Fig. 10 / Table 5: SLC energy per byte. Reuses the Table 3 SLC
/// runs; the measured quantity is nJ/B.
pub fn run_table5(requests: usize, pool: &ThreadPool) -> Vec<Cell> {
    run_table5_with(requests, pool, EngineConfig::default())
}

/// [`run_table5`] with an explicit per-sim engine configuration.
pub fn run_table5_with(requests: usize, pool: &ThreadPool, engine: EngineConfig) -> Vec<Cell> {
    let mut cells = run_table3_with(requests, pool, engine);
    cells.retain(|c| c.cell == CellType::Slc);
    // Swap the paper reference for the energy table.
    for c in &mut cells {
        let (_, rows) = paper::TABLE5
            .iter()
            .find(|(m, _)| *m == c.mode)
            .expect("mode in table5");
        let wi = paper::WAYS.iter().position(|&w| w == c.ways).unwrap();
        c.paper = Some(rows[wi][paper::iface_index(c.iface)]);
    }
    cells
}

/// Render a table of cells; `energy` selects the nJ/B column.
pub fn render_cells(title: &str, cells: &[Cell], energy: bool) -> String {
    let mut t = Table::new(vec![
        "cell", "mode", "ch", "ways", "iface", "measured", "paper", "delta",
    ]);
    for c in cells {
        let measured = if energy {
            c.report.energy_nj_per_byte
        } else {
            c.report.bandwidth_mbps
        };
        let (paper_s, delta_s) = match c.paper {
            Some(p) => (
                format!("{p:.2}"),
                format!("{:+.1}%", (measured - p) / p * 100.0),
            ),
            None => ("max".to_string(), "-".to_string()),
        };
        t.row(vec![
            c.cell.name().to_string(),
            c.mode.name().to_string(),
            c.channels.to_string(),
            c.ways.to_string(),
            c.iface.name().to_string(),
            format!("{measured:.2}"),
            paper_s,
            delta_s,
        ]);
    }
    format!("{title}\n\n{}", t.render())
}

/// Specification of the E6 open-loop load sweep (`ddrnand sweep-load`):
/// offered load is swept over a grid and the achieved throughput plus
/// latency percentiles are measured per interface × way count, producing
/// the throughput–latency "hockey stick" (EXPERIMENTS.md §Load).
#[derive(Debug, Clone)]
pub struct LoadSweepSpec {
    pub cell: CellType,
    pub mode: RequestKind,
    pub channels: u16,
    /// Way counts to sweep (each × all three interfaces).
    pub ways: Vec<u16>,
    /// Requests per point.
    pub requests: usize,
    /// Offered-load grid: `points` evenly spaced steps up to `max_mbps`.
    pub points: usize,
    pub max_mbps: f64,
    pub arrival: ArrivalKind,
    pub burst: u32,
    /// Per-sim engine configuration (threads / window override).
    pub engine: EngineConfig,
    pub seed: u64,
}

impl Default for LoadSweepSpec {
    fn default() -> Self {
        LoadSweepSpec {
            cell: CellType::Slc,
            mode: RequestKind::Read,
            channels: 1,
            ways: vec![1, 4, 8],
            requests: DEFAULT_REQUESTS,
            points: 8,
            // Past the SATA2 payload ceiling, so every configuration's
            // saturation knee falls inside the grid.
            max_mbps: 320.0,
            arrival: ArrivalKind::Poisson,
            burst: 4,
            engine: EngineConfig::default(),
            seed: 0xDD12_7A5D,
        }
    }
}

/// One measured point of the E6 load sweep.
#[derive(Debug, Clone)]
pub struct LoadCell {
    pub iface: InterfaceKind,
    pub ways: u16,
    /// Offered load of the grid point (MB/s).
    pub offered_mbps: f64,
    pub report: SimReport,
}

/// E6 — open-loop offered-load sweep across interfaces × way counts.
pub fn run_load_sweep(spec: &LoadSweepSpec, pool: &ThreadPool) -> Vec<LoadCell> {
    assert!(spec.points >= 1, "need at least one grid point");
    assert!(spec.max_mbps > 0.0, "max offered load must be positive");
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for iface in InterfaceKind::ALL.iter() {
        for &ways in &spec.ways {
            for p in 1..=spec.points {
                let offered = spec.max_mbps * p as f64 / spec.points as f64;
                let mut c = cfg(*iface, spec.cell, spec.channels, ways);
                c.load.offered_mbps = Some(offered);
                c.load.arrival = spec.arrival;
                c.load.burst = spec.burst;
                c.engine = spec.engine;
                c.seed = spec.seed;
                let mode = spec.mode;
                let requests = spec.requests;
                meta.push((*iface, ways, offered));
                jobs.push(move |ws: &mut SimWorkspace| {
                    Campaign::new(c, mode, requests).run_in(ws)
                });
            }
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((iface, ways, offered_mbps), report)| LoadCell {
            iface,
            ways,
            offered_mbps,
            report,
        })
        .collect()
}

/// Saturation knee of one `(offered, achieved)` curve: the highest offered
/// load (MB/s) the device still sustains, i.e. the last grid point whose
/// achieved throughput is within 5% of offered. When even the lightest
/// point is saturated, falls back to the best achieved throughput.
pub fn knee_mbps(points: &[(f64, f64)]) -> f64 {
    let mut knee = f64::NAN;
    for &(offered, achieved) in points {
        if achieved >= 0.95 * offered {
            knee = if knee.is_nan() { offered } else { knee.max(offered) };
        }
    }
    if knee.is_nan() {
        points.iter().map(|&(_, a)| a).fold(0.0, f64::max)
    } else {
        knee
    }
}

/// Render the load sweep as a table plus per-configuration knee summary.
/// In CSV mode only the machine-readable table is emitted (no title or
/// knee free text), so the output pipes straight into CSV consumers.
pub fn render_load_sweep(title: &str, cells: &[LoadCell], csv: bool) -> String {
    let mut t = Table::new(vec![
        "iface", "ways", "offered", "achieved", "p50_us", "p95_us", "p99_us", "mean_us",
    ]);
    for c in cells {
        t.row(vec![
            c.iface.name().to_string(),
            c.ways.to_string(),
            format!("{:.1}", c.offered_mbps),
            format!("{:.2}", c.report.bandwidth_mbps),
            format!("{:.1}", c.report.latency_p50_us),
            format!("{:.1}", c.report.latency_p95_us),
            format!("{:.1}", c.report.latency_p99_us),
            format!("{:.1}", c.report.latency_mean_us),
        ]);
    }
    if csv {
        return t.to_csv();
    }
    let mut out = format!("{title}\n\n{}\n", t.render());
    let mut seen: Vec<(InterfaceKind, u16)> = Vec::new();
    for c in cells {
        if !seen.contains(&(c.iface, c.ways)) {
            seen.push((c.iface, c.ways));
        }
    }
    out.push_str("saturation knees (highest sustained offered load):\n");
    for (iface, ways) in seen {
        let pts: Vec<(f64, f64)> = cells
            .iter()
            .filter(|c| c.iface == iface && c.ways == ways)
            .map(|c| (c.offered_mbps, c.report.bandwidth_mbps))
            .collect();
        let sustained = pts.iter().any(|&(o, a)| a >= 0.95 * o);
        if sustained {
            out.push_str(&format!(
                "  {:<9} x{:<2} way: {:>7.1} MB/s\n",
                iface.name(),
                ways,
                knee_mbps(&pts)
            ));
        } else {
            // No offered point was sustained: the knee lies below the
            // grid; report the peak achieved throughput honestly instead
            // of dressing it up as a sustained offered load.
            out.push_str(&format!(
                "  {:<9} x{:<2} way: below grid (peak achieved {:.1} MB/s)\n",
                iface.name(),
                ways,
                knee_mbps(&pts)
            ));
        }
    }
    out
}

/// Specification of the E7 steady-state sweep (`ddrnand sweep-steady`):
/// preconditioned drives under sustained uniform-random writes, swept over
/// over-provisioning × interface × way count. Measures the axis neither the
/// fresh-drive tables nor the load sweep can: **write amplification and the
/// GC tax on tail latency** (EXPERIMENTS.md §Steady-State).
#[derive(Debug, Clone)]
pub struct SteadySweepSpec {
    pub cell: CellType,
    pub channels: u16,
    /// Way counts to sweep (each × all three interfaces).
    pub ways: Vec<u16>,
    /// Over-provisioning fractions to sweep (logical = physical × (1−op)).
    pub over_provision: Vec<f64>,
    /// Sustained random-write requests per point (not clamped; wrap-around
    /// rewrites are the point).
    pub requests: usize,
    /// Offered write load in MB/s driving the open-loop arrival track;
    /// `None` = closed loop (queue-depth driven).
    pub offered_mbps: Option<f64>,
    pub arrival: ArrivalKind,
    pub burst: u32,
    /// Blocks per chip — small enough that GC reaches its sustained regime
    /// within `requests`.
    pub blocks_per_chip: u32,
    /// Coordinator wear-leveling P/E-spread threshold (0 = off).
    pub wear_level_spread: u32,
    /// Per-sim engine configuration (threads / window override).
    pub engine: EngineConfig,
    pub seed: u64,
}

impl Default for SteadySweepSpec {
    fn default() -> Self {
        SteadySweepSpec {
            cell: CellType::Slc,
            channels: 1,
            ways: vec![4, 8],
            over_provision: vec![0.07, 0.15, 0.28],
            requests: DEFAULT_REQUESTS,
            // Below the fresh-drive write ceiling of every 4-way config,
            // but above what a GC-taxed CONV drive sustains at ~7% OP —
            // the regime where the interfaces separate on the p99 axis.
            offered_mbps: Some(20.0),
            arrival: ArrivalKind::Poisson,
            burst: 4,
            blocks_per_chip: 64,
            wear_level_spread: 16,
            engine: EngineConfig::default(),
            seed: 0xDD12_7A5D,
        }
    }
}

/// One measured point of the E7 steady-state sweep.
#[derive(Debug, Clone)]
pub struct SteadyCell {
    pub iface: InterfaceKind,
    pub ways: u16,
    pub over_provision: f64,
    pub report: SimReport,
}

/// E7 — steady-state sweep: over-provisioning × interface × way count under
/// sustained random writes on a preconditioned drive.
pub fn run_steady_state(spec: &SteadySweepSpec, pool: &ThreadPool) -> Vec<SteadyCell> {
    assert!(!spec.ways.is_empty(), "need at least one way count");
    assert!(
        !spec.over_provision.is_empty(),
        "need at least one over-provisioning point"
    );
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for iface in InterfaceKind::ALL.iter() {
        for &ways in &spec.ways {
            for &op in &spec.over_provision {
                assert!(
                    op > 0.0 && op < 0.5,
                    "over-provisioning fraction {op} out of (0, 0.5)"
                );
                let mut c = cfg(*iface, spec.cell, spec.channels, ways);
                c.blocks_per_chip = spec.blocks_per_chip;
                c.steady.enabled = true;
                c.steady.over_provision = op;
                // The shared headroom rule config validation enforces for
                // TOML: fail loudly here, not with a live-lock assert
                // mid-sweep.
                assert!(
                    c.steady.gc_headroom_ok(spec.blocks_per_chip),
                    "over-provisioning {op} too small for {} blocks/chip: \
                     GC needs spare blocks beyond the trigger threshold",
                    spec.blocks_per_chip
                );
                c.steady.wear_level_spread = spec.wear_level_spread;
                c.engine = spec.engine;
                c.seed = spec.seed;
                if let Some(offered) = spec.offered_mbps {
                    c.load.offered_mbps = Some(offered);
                    c.load.arrival = spec.arrival;
                    c.load.burst = spec.burst;
                }
                let requests = spec.requests;
                meta.push((*iface, ways, op));
                jobs.push(move |ws: &mut SimWorkspace| {
                    Campaign::new(c, RequestKind::Write, requests).run_in(ws)
                });
            }
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((iface, ways, over_provision), report)| SteadyCell {
            iface,
            ways,
            over_provision,
            report,
        })
        .collect()
}

/// Render the steady-state sweep as a table plus a per-configuration GC-tax
/// summary. In CSV mode only the machine-readable table is emitted.
pub fn render_steady_sweep(title: &str, cells: &[SteadyCell], csv: bool) -> String {
    let mut t = Table::new(vec![
        "iface", "ways", "op", "waf", "achieved", "p99_us", "p99_gc_us", "p99_clean_us",
        "erases", "spread", "gc_e_pct",
    ]);
    for c in cells {
        t.row(vec![
            c.iface.name().to_string(),
            c.ways.to_string(),
            format!("{:.2}", c.over_provision),
            format!("{:.3}", c.report.waf),
            format!("{:.2}", c.report.bandwidth_mbps),
            format!("{:.1}", c.report.latency_p99_us),
            format!("{:.1}", c.report.latency_p99_gc_us),
            format!("{:.1}", c.report.latency_p99_clean_us),
            c.report.blocks_erased.to_string(),
            c.report.wear_spread.to_string(),
            format!("{:.1}", c.report.gc_energy_share * 100.0),
        ]);
    }
    if csv {
        return t.to_csv();
    }
    let mut out = format!("{title}\n\n{}\n", t.render());
    let mut seen: Vec<(InterfaceKind, u16)> = Vec::new();
    for c in cells {
        if !seen.contains(&(c.iface, c.ways)) {
            seen.push((c.iface, c.ways));
        }
    }
    out.push_str("GC tax across the over-provisioning grid (first -> last op point):\n");
    for (iface, ways) in seen {
        let pts: Vec<&SteadyCell> = cells
            .iter()
            .filter(|c| c.iface == iface && c.ways == ways)
            .collect();
        let (first, last) = (pts.first().expect("seen implies cells"), pts.last().unwrap());
        out.push_str(&format!(
            "  {:<9} x{:<2} way: WAF {:.3} -> {:.3}, p99 {:.1} -> {:.1} us\n",
            iface.name(),
            ways,
            first.report.waf,
            last.report.waf,
            first.report.latency_p99_us,
            last.report.latency_p99_us,
        ));
    }
    out
}

/// Specification of the E8 tiered-flash sweep (`ddrnand sweep-tiered`):
/// a fixed-capacity MLC-geometry drive whose SLC-tier chip fraction is
/// swept from pure MLC (fraction 0 — tiering disabled) through combined
/// SLC/MLC partitions to every chip in SLC mode (fraction 1), per
/// interface × way count. Measures the write-latency face of the SLC
/// write-buffer architecture, plus migration traffic and its WAF cost
/// (EXPERIMENTS.md §Tiering).
#[derive(Debug, Clone)]
pub struct TieredSweepSpec {
    pub channels: u16,
    /// Way counts to sweep.
    pub ways: Vec<u16>,
    /// SLC-tier chip fractions in [0, 1]; 0 = tiering disabled (pure MLC).
    pub slc_fractions: Vec<f64>,
    /// Interfaces to sweep (applied to both tiers).
    pub ifaces: Vec<InterfaceKind>,
    /// Requests per point.
    pub requests: usize,
    /// Offered write load in MB/s driving the open-loop arrival track;
    /// `None` = closed loop.
    pub offered_mbps: Option<f64>,
    pub arrival: ArrivalKind,
    pub burst: u32,
    /// Blocks per chip — small enough that the SLC tier overflows (and
    /// migration runs) within `requests`.
    pub blocks_per_chip: u32,
    /// SLC-chip free-block threshold that triggers migration.
    pub migrate_free_blocks: u32,
    /// Compose with the `[steady]` regime: preconditioned drive + uniform
    /// random writes, so migration and GC traffic interact.
    pub steady: bool,
    /// Over-provisioning fraction for the steady composition.
    pub over_provision: f64,
    /// Per-sim engine configuration (threads / window override).
    pub engine: EngineConfig,
    pub seed: u64,
}

impl Default for TieredSweepSpec {
    fn default() -> Self {
        TieredSweepSpec {
            channels: 1,
            ways: vec![4],
            slc_fractions: vec![0.0, 0.25, 0.5, 1.0],
            ifaces: vec![InterfaceKind::Conv, InterfaceKind::Proposed],
            requests: DEFAULT_REQUESTS,
            // Sustainable by every partition (pure MLC 4-way sustains
            // ~19 MB/s of t_PROG-bound writes) so the latency axis, not
            // saturation, separates the fractions.
            offered_mbps: Some(12.0),
            arrival: ArrivalKind::Poisson,
            burst: 4,
            blocks_per_chip: 64,
            migrate_free_blocks: 4,
            steady: false,
            over_provision: 0.07,
            engine: EngineConfig::default(),
            seed: 0xDD12_7A5D,
        }
    }
}

/// One measured point of the E8 tiered sweep.
#[derive(Debug, Clone)]
pub struct TieredCell {
    pub iface: InterfaceKind,
    pub ways: u16,
    /// SLC-tier chip fraction of the grid point (0 = tiering disabled).
    pub slc_fraction: f64,
    pub report: SimReport,
}

/// The configuration of one E8 grid point — shared by the driver and the
/// CLI's pre-flight validation so the two can never disagree. Returns the
/// config or every problem `SsdConfig::validate` found with it (e.g. the
/// tiering capacity-feasibility rule).
pub fn tiered_point_config(
    spec: &TieredSweepSpec,
    iface: InterfaceKind,
    ways: u16,
    fraction: f64,
) -> Result<SsdConfig, Vec<String>> {
    assert!(
        (0.0..=1.0).contains(&fraction),
        "SLC-tier fraction {fraction} out of [0, 1]"
    );
    let mut c = cfg(iface, CellType::Mlc, spec.channels, ways);
    c.blocks_per_chip = spec.blocks_per_chip;
    c.engine = spec.engine;
    c.seed = spec.seed;
    if fraction > 0.0 {
        c.tiering.enabled = true;
        c.tiering.slc_fraction = fraction;
        c.tiering.migrate_free_blocks = spec.migrate_free_blocks;
    }
    if spec.steady {
        c.steady.enabled = true;
        c.steady.over_provision = spec.over_provision;
    }
    if let Some(offered) = spec.offered_mbps {
        c.load.offered_mbps = Some(offered);
        c.load.arrival = spec.arrival;
        c.load.burst = spec.burst;
    }
    let errs = c.validate();
    if errs.is_empty() {
        Ok(c)
    } else {
        Err(errs)
    }
}

/// E8 — tiered-flash sweep: SLC-tier fraction × interface × way count at
/// fixed total capacity. The caller (CLI) pre-validates each grid point
/// via [`tiered_point_config`]; an invalid point here is a bug and
/// panics.
pub fn run_tiered_sweep(spec: &TieredSweepSpec, pool: &ThreadPool) -> Vec<TieredCell> {
    assert!(!spec.ways.is_empty(), "need at least one way count");
    assert!(!spec.ifaces.is_empty(), "need at least one interface");
    assert!(
        !spec.slc_fractions.is_empty(),
        "need at least one SLC-tier fraction"
    );
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for iface in &spec.ifaces {
        for &ways in &spec.ways {
            for &fraction in &spec.slc_fractions {
                let c = tiered_point_config(spec, *iface, ways, fraction)
                    .unwrap_or_else(|e| panic!("tiered sweep point invalid: {e:?}"));
                let requests = spec.requests;
                meta.push((*iface, ways, fraction));
                jobs.push(move |ws: &mut SimWorkspace| {
                    Campaign::new(c, RequestKind::Write, requests).run_in(ws)
                });
            }
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((iface, ways, slc_fraction), report)| TieredCell {
            iface,
            ways,
            slc_fraction,
            report,
        })
        .collect()
}

/// Render the tiered sweep as a table plus a per-configuration
/// pure-MLC-vs-tiered-vs-pure-SLC latency summary. In CSV mode only the
/// machine-readable table is emitted.
pub fn render_tiered_sweep(title: &str, cells: &[TieredCell], csv: bool) -> String {
    let mut t = Table::new(vec![
        "iface", "ways", "slc_frac", "achieved", "p50_us", "p99_us", "waf", "mig_prog",
        "mig_read", "erases",
    ]);
    for c in cells {
        t.row(vec![
            c.iface.name().to_string(),
            c.ways.to_string(),
            format!("{:.2}", c.slc_fraction),
            format!("{:.2}", c.report.bandwidth_mbps),
            format!("{:.1}", c.report.latency_p50_us),
            format!("{:.1}", c.report.latency_p99_us),
            format!("{:.3}", c.report.waf),
            c.report.mig_pages_programmed.to_string(),
            c.report.mig_pages_read.to_string(),
            c.report.blocks_erased.to_string(),
        ]);
    }
    if csv {
        return t.to_csv();
    }
    let mut out = format!("{title}\n\n{}\n", t.render());
    let mut seen: Vec<(InterfaceKind, u16)> = Vec::new();
    for c in cells {
        if !seen.contains(&(c.iface, c.ways)) {
            seen.push((c.iface, c.ways));
        }
    }
    out.push_str("write p50 across the SLC-fraction grid (first -> last point):\n");
    for (iface, ways) in seen {
        let pts: Vec<&TieredCell> = cells
            .iter()
            .filter(|c| c.iface == iface && c.ways == ways)
            .collect();
        let (first, last) = (pts.first().expect("seen implies cells"), pts.last().unwrap());
        out.push_str(&format!(
            "  {:<9} x{:<2} way: frac {:.2} -> {:.2}: p50 {:.1} -> {:.1} us, WAF {:.3} -> {:.3}\n",
            iface.name(),
            ways,
            first.slc_fraction,
            last.slc_fraction,
            first.report.latency_p50_us,
            last.report.latency_p50_us,
            first.report.waf,
            last.report.waf,
        ));
    }
    out
}

/// Specification of the E9 QoS sweep (`ddrnand sweep-qos`): a fixed
/// two-tenant mix — a latency-critical random-read tenant (class 0)
/// against a saturating bulk sequential-write tenant (class 2), each with
/// its own Poisson offered load, over the multi-queue host path — swept
/// across way-scheduler policy × interface × way count. Measures the axis
/// none of the single-stream sweeps can: **per-tenant latency isolation
/// under contention**, the read tenant's p99 and the fairness index per
/// scheduling policy (EXPERIMENTS.md §QoS).
#[derive(Debug, Clone)]
pub struct QosSweepSpec {
    pub cell: CellType,
    pub channels: u16,
    /// Way counts to sweep.
    pub ways: Vec<u16>,
    /// Interfaces to sweep.
    pub ifaces: Vec<InterfaceKind>,
    /// Way-scheduling policies to sweep.
    pub schedulers: Vec<SchedKind>,
    /// Host-link kind (the QoS lever is the way scheduler; multi-queue by
    /// default so per-queue accounting is exercised too).
    pub link: HostLinkKind,
    /// Offered load (MB/s) of the latency-critical random-read tenant.
    pub read_mbps: f64,
    /// Offered load (MB/s) of the bulk sequential-write tenant — above
    /// the device's write ceiling by default, so way queues actually
    /// contend.
    pub write_mbps: f64,
    /// Bulk-writer request count per point; the reader's count is derived
    /// so the two tenants' arrival spans roughly match.
    pub requests: usize,
    pub blocks_per_chip: u32,
    /// Per-sim engine configuration (threads / window override).
    pub engine: EngineConfig,
    pub seed: u64,
}

impl Default for QosSweepSpec {
    fn default() -> Self {
        QosSweepSpec {
            cell: CellType::Slc,
            channels: 1,
            ways: vec![4],
            ifaces: vec![InterfaceKind::Conv, InterfaceKind::Proposed],
            schedulers: SchedKind::ALL.to_vec(),
            link: HostLinkKind::MultiQueue,
            read_mbps: 4.0,
            // Above the ~29–39 MB/s 4-way write ceilings of every
            // interface: the bulk tenant saturates the ways.
            write_mbps: 55.0,
            requests: DEFAULT_REQUESTS,
            blocks_per_chip: 512,
            engine: EngineConfig::default(),
            seed: 0xDD12_7A5D,
        }
    }
}

impl QosSweepSpec {
    /// Reader request count: scaled so both tenants' arrival spans
    /// roughly coincide (floored so the percentile estimates have
    /// samples).
    pub fn read_requests(&self) -> usize {
        ((self.requests as f64 * self.read_mbps / self.write_mbps) as usize).max(16)
    }

    /// The two-tenant mix of one grid point.
    pub fn tenants(&self) -> Vec<TenantSpec> {
        vec![
            TenantSpec {
                mode: RequestKind::Read,
                pattern: AccessPattern::Random,
                class: CLASS_URGENT,
                requests: self.read_requests(),
                offered_mbps: Some(self.read_mbps),
            },
            TenantSpec {
                mode: RequestKind::Write,
                pattern: AccessPattern::Sequential,
                class: CLASS_BULK,
                requests: self.requests,
                offered_mbps: Some(self.write_mbps),
            },
        ]
    }
}

/// One measured point of the E9 QoS sweep.
#[derive(Debug, Clone)]
pub struct QosCell {
    pub iface: InterfaceKind,
    pub ways: u16,
    pub sched: SchedKind,
    pub report: SimReport,
}

/// The configuration of one E9 grid point — shared by the driver and the
/// CLI's pre-flight validation so the two can never disagree.
pub fn qos_point_config(
    spec: &QosSweepSpec,
    iface: InterfaceKind,
    ways: u16,
    sched: SchedKind,
) -> Result<SsdConfig, Vec<String>> {
    let mut c = cfg(iface, spec.cell, spec.channels, ways);
    c.blocks_per_chip = spec.blocks_per_chip;
    c.engine = spec.engine;
    c.seed = spec.seed;
    c.host.link = spec.link;
    c.host.queues = 2;
    c.qos.scheduler = sched;
    let errs = c.validate();
    if errs.is_empty() {
        Ok(c)
    } else {
        Err(errs)
    }
}

/// E9 — QoS sweep: two-tenant mix × scheduler policy × interface × way
/// count, open loop via per-tenant arrival tracks.
pub fn run_qos_sweep(spec: &QosSweepSpec, pool: &ThreadPool) -> Vec<QosCell> {
    assert!(!spec.ways.is_empty(), "need at least one way count");
    assert!(!spec.ifaces.is_empty(), "need at least one interface");
    assert!(!spec.schedulers.is_empty(), "need at least one scheduler");
    assert!(
        spec.read_mbps > 0.0 && spec.write_mbps > 0.0,
        "tenant offered loads must be positive"
    );
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for iface in &spec.ifaces {
        for &ways in &spec.ways {
            for &sched in &spec.schedulers {
                let c = qos_point_config(spec, *iface, ways, sched)
                    .unwrap_or_else(|e| panic!("qos sweep point invalid: {e:?}"));
                let tenants = spec.tenants();
                meta.push((*iface, ways, sched));
                jobs.push(move |ws: &mut SimWorkspace| {
                    Campaign::multi_tenant(c, tenants).run_in(ws)
                });
            }
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((iface, ways, sched), report)| QosCell {
            iface,
            ways,
            sched,
            report,
        })
        .collect()
}

/// Render the QoS sweep: one row per grid point per stream, plus a
/// per-configuration summary of the latency-critical tenant's p99 across
/// scheduling policies. In CSV mode only the machine-readable table is
/// emitted.
pub fn render_qos_sweep(title: &str, cells: &[QosCell], csv: bool) -> String {
    let mut t = Table::new(vec![
        "iface", "ways", "sched", "stream", "class", "reqs", "achieved", "p50_us", "p99_us",
        "fairness",
    ]);
    for c in cells {
        for s in &c.report.streams {
            t.row(vec![
                c.iface.name().to_string(),
                c.ways.to_string(),
                c.sched.name().to_string(),
                s.stream.to_string(),
                s.class.to_string(),
                s.requests.to_string(),
                format!("{:.2}", s.bandwidth_mbps),
                format!("{:.1}", s.latency_p50_us),
                format!("{:.1}", s.latency_p99_us),
                format!("{:.3}", c.report.fairness),
            ]);
        }
    }
    if csv {
        return t.to_csv();
    }
    let mut out = format!("{title}\n\n{}\n", t.render());
    let mut seen: Vec<(InterfaceKind, u16)> = Vec::new();
    for c in cells {
        if !seen.contains(&(c.iface, c.ways)) {
            seen.push((c.iface, c.ways));
        }
    }
    out.push_str("latency-critical tenant p99 / total MB/s by scheduling policy:\n");
    for (iface, ways) in seen {
        let mut line = format!("  {:<9} x{:<2} way:", iface.name(), ways);
        for c in cells.iter().filter(|c| c.iface == iface && c.ways == ways) {
            let read_p99 = c
                .report
                .streams
                .first()
                .map(|s| s.latency_p99_us)
                .unwrap_or(f64::NAN);
            line.push_str(&format!(
                "  {} {:.1} us / {:.1}",
                c.sched.name(),
                read_p99,
                c.report.bandwidth_mbps
            ));
        }
        line.push('\n');
        out.push_str(&line);
    }
    out
}

/// E10 — bottleneck sweep spec: single-workload grid across interface ×
/// way count with the `[observe]` occupancy accounting enabled, so the
/// utilization/stall table explains *why* each point's bandwidth lands
/// where it does (EXPERIMENTS.md §Bottlenecks).
#[derive(Debug, Clone)]
pub struct ObserveSweepSpec {
    pub cell: CellType,
    pub channels: u16,
    /// Way counts to sweep.
    pub ways: Vec<u16>,
    /// Interfaces to sweep.
    pub ifaces: Vec<InterfaceKind>,
    /// Workload shape (the paper's fresh-drive sequential pattern).
    pub mode: RequestKind,
    pub requests: usize,
    pub blocks_per_chip: u32,
    /// Also record the Chrome-trace timeline per point (`--trace` on the
    /// CLI requires a single grid point, where the timeline is meaningful).
    pub timeline: bool,
    /// Per-sim engine configuration (threads / window override).
    pub engine: EngineConfig,
    pub seed: u64,
}

impl Default for ObserveSweepSpec {
    fn default() -> Self {
        ObserveSweepSpec {
            cell: CellType::Slc,
            channels: 1,
            ways: vec![1, 2, 4, 8],
            ifaces: InterfaceKind::ALL.to_vec(),
            mode: RequestKind::Write,
            requests: DEFAULT_REQUESTS,
            blocks_per_chip: 512,
            timeline: false,
            engine: EngineConfig::default(),
            seed: 0xDD12_7A5D,
        }
    }
}

/// One measured point of the E10 bottleneck sweep.
#[derive(Debug, Clone)]
pub struct ObserveCell {
    pub iface: InterfaceKind,
    pub ways: u16,
    pub report: SimReport,
}

/// The configuration of one E10 grid point — shared by the driver and the
/// CLI's pre-flight validation so the two can never disagree.
pub fn observe_point_config(
    spec: &ObserveSweepSpec,
    iface: InterfaceKind,
    ways: u16,
) -> Result<SsdConfig, Vec<String>> {
    let mut c = cfg(iface, spec.cell, spec.channels, ways);
    c.blocks_per_chip = spec.blocks_per_chip;
    c.engine = spec.engine;
    c.seed = spec.seed;
    c.observe.enabled = true;
    c.observe.timeline = spec.timeline;
    let errs = c.validate();
    if errs.is_empty() {
        Ok(c)
    } else {
        Err(errs)
    }
}

/// E10 — bottleneck sweep: run the grid with occupancy accounting on and
/// return every point's report (each carrying its `observe` section).
pub fn run_observe_sweep(spec: &ObserveSweepSpec, pool: &ThreadPool) -> Vec<ObserveCell> {
    assert!(!spec.ways.is_empty(), "need at least one way count");
    assert!(!spec.ifaces.is_empty(), "need at least one interface");
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for iface in &spec.ifaces {
        for &ways in &spec.ways {
            let c = observe_point_config(spec, *iface, ways)
                .unwrap_or_else(|e| panic!("observe sweep point invalid: {e:?}"));
            let mode = spec.mode;
            let requests = spec.requests;
            meta.push((*iface, ways));
            jobs.push(move |ws: &mut SimWorkspace| Campaign::new(c, mode, requests).run_in(ws));
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((iface, ways), report)| ObserveCell {
            iface,
            ways,
            report,
        })
        .collect()
}

/// Render the bottleneck sweep: one row per grid point per resource kind
/// (the CSV utilization table), plus — in text mode — a per-point
/// stall-attribution summary linking the occupancy split to the measured
/// bandwidth.
pub fn render_observe_sweep(title: &str, cells: &[ObserveCell], csv: bool) -> String {
    use crate::observe::ResourceKind;
    let mut t = Table::new(vec![
        "iface",
        "ways",
        "resource",
        "busy_ps",
        "blocked_ps",
        "queued_ps",
        "idle_ps",
        "busy_pct",
        "blocked_pct",
    ]);
    for c in cells {
        let Some(o) = &c.report.observe else { continue };
        for kind in [ResourceKind::Bus, ResourceKind::Way, ResourceKind::Chip] {
            let [busy, blocked, queued, idle] = o.totals(kind);
            let total = (busy + blocked + queued + idle).max(1);
            t.row(vec![
                c.iface.name().to_string(),
                c.ways.to_string(),
                kind.name().to_string(),
                busy.to_string(),
                blocked.to_string(),
                queued.to_string(),
                idle.to_string(),
                format!("{:.2}", busy as f64 / total as f64 * 100.0),
                format!("{:.2}", blocked as f64 / total as f64 * 100.0),
            ]);
        }
    }
    if csv {
        return t.to_csv();
    }
    let mut out = format!("{title}\n\n{}\n", t.render());
    out.push_str("stall attribution (ps) and throughput by grid point:\n");
    for c in cells {
        let Some(o) = &c.report.observe else { continue };
        out.push_str(&format!(
            "  {:<9} x{:<2} way: contention {}, gc barrier {}, map fill {}, \
             starvation {}, backpressure {}; {} gc triggers; {:.2} MB/s\n",
            c.iface.name(),
            c.ways,
            o.stalls.bus_contention_ps,
            o.stalls.gc_barrier_ps,
            o.stalls.map_fill_ps,
            o.stalls.queue_starvation_ps,
            o.stalls.link_backpressure_ps,
            o.gc_triggers,
            c.report.bandwidth_mbps,
        ));
    }
    out
}

/// E11 — demand-paged mapping sweep spec: cache capacity × workload
/// locality grid with the `[mapping]` tier enabled, so the hit-rate /
/// translation-overhead tradeoff of DFTL-style map caching is measured
/// under real flash contention (EXPERIMENTS.md §Mapping).
#[derive(Debug, Clone)]
pub struct MapSweepSpec {
    pub cell: CellType,
    pub iface: InterfaceKind,
    pub channels: u16,
    pub ways: u16,
    /// `Demand` stalls host ops on a map miss; `Fmmu` overlaps the fill
    /// with array access (contention-only cost).
    pub map_mode: MapMode,
    /// Workload request kind.
    pub mode: RequestKind,
    pub requests: usize,
    pub blocks_per_chip: u32,
    /// Logical-to-physical entries packed per translation page.
    pub entries_per_page: u32,
    /// Cache capacities (translation pages) to sweep.
    pub cache_pages: Vec<u64>,
    /// Locality points to sweep: `(hot_fraction, hot_prob)` as consumed by
    /// [`TraceGen::hotspot`]; `(1.0, 1.0)` is effectively uniform random.
    pub locality: Vec<(f64, f64)>,
    /// Per-sim engine configuration (threads / window override).
    pub engine: EngineConfig,
    pub seed: u64,
}

impl Default for MapSweepSpec {
    fn default() -> Self {
        MapSweepSpec {
            cell: CellType::Slc,
            iface: InterfaceKind::Proposed,
            channels: 4,
            ways: 4,
            map_mode: MapMode::Demand,
            mode: RequestKind::Write,
            requests: 2 * DEFAULT_REQUESTS,
            blocks_per_chip: 512,
            entries_per_page: 1024,
            // Default grid spans starved -> comfortable -> fully resident
            // (the 4x4x512-block SLC geometry has ~461 translation pages).
            cache_pages: vec![32, 128, 512],
            locality: vec![(0.05, 0.95), (0.2, 0.8), (1.0, 1.0)],
            engine: EngineConfig::default(),
            seed: 0xDD11_3A9B,
        }
    }
}

/// One measured point of the E11 mapping sweep.
#[derive(Debug, Clone)]
pub struct MapCell {
    pub cache_pages: u64,
    pub hot_fraction: f64,
    pub hot_prob: f64,
    pub report: SimReport,
}

/// The configuration of one E11 grid point — shared by the driver and the
/// CLI's pre-flight validation so the two can never disagree.
pub fn map_point_config(spec: &MapSweepSpec, cache_pages: u64) -> Result<SsdConfig, Vec<String>> {
    let mut c = cfg(spec.iface, spec.cell, spec.channels, spec.ways);
    c.blocks_per_chip = spec.blocks_per_chip;
    c.engine = spec.engine;
    c.seed = spec.seed;
    c.mapping.mode = spec.map_mode;
    c.mapping.cache_pages = cache_pages;
    c.mapping.entries_per_page = spec.entries_per_page;
    let errs = c.validate();
    if errs.is_empty() {
        Ok(c)
    } else {
        Err(errs)
    }
}

/// E11 — mapping sweep: for each locality point build one hotspot trace
/// (shared across cache sizes so only the cache capacity varies along that
/// axis) and run it at every cache capacity. Uses explicit traces through
/// [`SimWorkspace::run_trace`] rather than [`Campaign`], which only knows
/// sequential/uniform-random shapes.
pub fn run_map_sweep(spec: &MapSweepSpec, pool: &ThreadPool) -> Vec<MapCell> {
    assert!(!spec.cache_pages.is_empty(), "need at least one cache size");
    assert!(!spec.locality.is_empty(), "need at least one locality point");
    let gen = TraceGen::default();
    let mut jobs = Vec::new();
    let mut meta = Vec::new();
    for &(hot_fraction, hot_prob) in &spec.locality {
        for &cache_pages in &spec.cache_pages {
            let c = map_point_config(spec, cache_pages)
                .unwrap_or_else(|e| panic!("map sweep point invalid: {e:?}"));
            let nand = c.nand_timing();
            let physical =
                c.chips() as u64 * c.blocks_per_chip as u64 * nand.pages_per_block as u64;
            let volume = c.logical_pages(physical) * nand.page_bytes as u64;
            let trace =
                gen.hotspot(spec.mode, spec.requests, volume, hot_fraction, hot_prob, spec.seed);
            meta.push((cache_pages, hot_fraction, hot_prob));
            jobs.push(move |ws: &mut SimWorkspace| ws.run_trace(&c, &trace));
        }
    }
    let reports = pool.run_all_with(jobs, SimWorkspace::new);
    meta.into_iter()
        .zip(reports)
        .map(|((cache_pages, hot_fraction, hot_prob), report)| MapCell {
            cache_pages,
            hot_fraction,
            hot_prob,
            report,
        })
        .collect()
}

/// Render the mapping sweep: one row per (locality, cache size) point with
/// the cache hit rate, the translation traffic it injected, and the
/// bandwidth cost.
pub fn render_map_sweep(title: &str, cells: &[MapCell], csv: bool) -> String {
    let mut t = Table::new(vec![
        "cache_tpages",
        "hot_frac",
        "hot_prob",
        "hit_pct",
        "map_reads",
        "map_writebacks",
        "deferred",
        "map_wait_us",
        "mbps",
    ]);
    for c in cells {
        let r = &c.report;
        let hit_pct = if r.map_hits + r.map_misses > 0 {
            format!("{:.2}", r.map_hit_rate * 100.0)
        } else {
            "n/a".to_string()
        };
        let wait = if r.map_deferred > 0 {
            format!("{:.2}", r.map_wait_mean_us)
        } else {
            "0.00".to_string()
        };
        t.row(vec![
            c.cache_pages.to_string(),
            format!("{:.2}", c.hot_fraction),
            format!("{:.2}", c.hot_prob),
            hit_pct,
            r.map_pages_read.to_string(),
            r.map_pages_programmed.to_string(),
            r.map_deferred.to_string(),
            wait,
            format!("{:.2}", r.bandwidth_mbps),
        ]);
    }
    if csv {
        return t.to_csv();
    }
    format!("{title}\n\n{}", t.render())
}

/// E5 — §6 headline: min/max PROPOSED/CONV ratios from Table 3 cells.
pub fn headline(cells: &[Cell]) -> String {
    let mut out = String::from("E5 / §6 headline — PROPOSED/CONV ratio ranges (paper: SLC read 1.65–2.76x, write 1.09–2.45x; MLC read 1.64–2.66x, write 1.05–1.76x)\n\n");
    for cell in [CellType::Slc, CellType::Mlc] {
        for mode in [RequestKind::Read, RequestKind::Write] {
            let mut ratios = Vec::new();
            for &w in &paper::WAYS {
                let find = |iface| {
                    cells
                        .iter()
                        .find(|c| {
                            c.cell == cell && c.mode == mode && c.ways == w && c.iface == iface
                        })
                        .map(|c| c.report.bandwidth_mbps)
                };
                if let (Some(p), Some(conv)) =
                    (find(InterfaceKind::Proposed), find(InterfaceKind::Conv))
                {
                    ratios.push(p / conv);
                }
            }
            if !ratios.is_empty() {
                let lo = ratios.iter().cloned().fold(f64::INFINITY, f64::min);
                let hi = ratios.iter().cloned().fold(0.0f64, f64::max);
                out.push_str(&format!(
                    "  {cell} {:<5}: {lo:.2}x – {hi:.2}x\n",
                    mode.name()
                ));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_text_contains_paper_values() {
        let t = table2_text();
        assert!(t.contains("19.81"));
        assert!(t.contains("83"));
    }

    #[test]
    fn table3_grid_shape() {
        let pool = ThreadPool::new(0);
        let cells = run_table3(30, &pool);
        assert_eq!(cells.len(), 4 * 5 * 3); // 4 (cell,mode) x 5 ways x 3 ifaces
        assert!(cells.iter().all(|c| c.report.bandwidth_mbps > 0.0));
        let rendered = render_cells("t3", &cells, false);
        assert!(rendered.contains("PROPOSED"));
    }

    #[test]
    fn table5_reuses_slc_and_swaps_reference() {
        let pool = ThreadPool::new(0);
        let cells = run_table5(30, &pool);
        assert_eq!(cells.len(), 2 * 5 * 3);
        assert!(cells.iter().all(|c| c.cell == CellType::Slc));
        // 16-way write PROPOSED paper value is 0.48 nJ/B.
        let c = cells
            .iter()
            .find(|c| c.ways == 16 && c.iface == InterfaceKind::Proposed && c.mode == RequestKind::Write)
            .unwrap();
        assert_eq!(c.paper, Some(0.48));
    }

    #[test]
    fn knee_picks_last_sustained_point() {
        // Sustains 40 and 80, saturates past that.
        let pts = [(40.0, 39.8), (80.0, 78.5), (120.0, 90.0), (160.0, 91.0)];
        assert_eq!(knee_mbps(&pts), 80.0);
        // Saturated from the first point: fall back to best achieved.
        let sat = [(100.0, 50.0), (200.0, 55.0)];
        assert_eq!(knee_mbps(&sat), 55.0);
    }

    #[test]
    fn load_sweep_grid_shape_and_rendering() {
        let pool = ThreadPool::new(0);
        let spec = LoadSweepSpec {
            ways: vec![2],
            requests: 15,
            points: 2,
            max_mbps: 120.0,
            ..LoadSweepSpec::default()
        };
        let cells = run_load_sweep(&spec, &pool);
        assert_eq!(cells.len(), 3 * 1 * 2); // 3 ifaces x 1 way count x 2 points
        for c in &cells {
            assert!(c.report.bandwidth_mbps > 0.0);
            assert!(c.report.latency_p99_us >= c.report.latency_p50_us);
            assert!(c.offered_mbps > 0.0);
        }
        let rendered = render_load_sweep("t", &cells, false);
        assert!(rendered.contains("saturation knees"));
        assert!(rendered.contains("PROPOSED"));
        let csv = render_load_sweep("t", &cells, true);
        assert!(csv.contains("iface,ways,offered"));
    }

    #[test]
    fn steady_sweep_grid_shape_and_rendering() {
        let pool = ThreadPool::new(0);
        let spec = SteadySweepSpec {
            ways: vec![2],
            over_provision: vec![0.07, 0.25],
            requests: 120,
            blocks_per_chip: 64,
            offered_mbps: None, // closed loop keeps the unit test fast
            ..SteadySweepSpec::default()
        };
        let cells = run_steady_state(&spec, &pool);
        assert_eq!(cells.len(), 3 * 1 * 2); // 3 ifaces x 1 way count x 2 op points
        for c in &cells {
            assert!(c.report.bandwidth_mbps > 0.0);
            assert!(c.report.waf >= 1.0, "waf={}", c.report.waf);
            assert!(c.report.blocks_erased > 0, "steady runs must GC");
        }
        // More over-provisioning -> less amplification (same iface/ways).
        for iface in InterfaceKind::ALL.iter() {
            let find = |op: f64| {
                cells
                    .iter()
                    .find(|c| c.iface == *iface && (c.over_provision - op).abs() < 1e-9)
                    .map(|c| c.report.waf)
                    .unwrap()
            };
            assert!(
                find(0.07) >= find(0.25),
                "{iface:?}: WAF must not grow with over-provisioning"
            );
        }
        let rendered = render_steady_sweep("t", &cells, false);
        assert!(rendered.contains("GC tax"));
        assert!(rendered.contains("PROPOSED"));
        let csv = render_steady_sweep("t", &cells, true);
        assert!(csv.contains("iface,ways,op,waf"));
    }

    #[test]
    fn tiered_sweep_grid_shape_and_rendering() {
        let pool = ThreadPool::new(0);
        let spec = TieredSweepSpec {
            ways: vec![2],
            slc_fractions: vec![0.0, 0.5],
            ifaces: vec![InterfaceKind::Proposed],
            requests: 12,
            offered_mbps: None, // closed loop keeps the unit test fast
            blocks_per_chip: 64,
            ..TieredSweepSpec::default()
        };
        let cells = run_tiered_sweep(&spec, &pool);
        assert_eq!(cells.len(), 2); // 1 iface x 1 way count x 2 fractions
        for c in &cells {
            assert!(c.report.bandwidth_mbps > 0.0);
            assert!(c.report.requests == 12);
        }
        // The fraction-0 baseline is a plain MLC drive.
        let base = cells.iter().find(|c| c.slc_fraction == 0.0).unwrap();
        assert_eq!(base.report.mig_pages_programmed, 0);
        assert_eq!(base.report.waf, 1.0);
        let rendered = render_tiered_sweep("t", &cells, false);
        assert!(rendered.contains("SLC-fraction grid"));
        assert!(rendered.contains("PROPOSED"));
        let csv = render_tiered_sweep("t", &cells, true);
        assert!(csv.contains("iface,ways,slc_frac"));
    }

    #[test]
    fn qos_sweep_grid_shape_and_rendering() {
        let pool = ThreadPool::new(0);
        let spec = QosSweepSpec {
            ways: vec![2],
            ifaces: vec![InterfaceKind::Proposed],
            schedulers: vec![SchedKind::RoundRobin, SchedKind::ReadPriority],
            requests: 30,
            write_mbps: 40.0,
            read_mbps: 4.0,
            blocks_per_chip: 128,
            ..QosSweepSpec::default()
        };
        let cells = run_qos_sweep(&spec, &pool);
        assert_eq!(cells.len(), 2); // 1 iface x 1 way count x 2 policies
        for c in &cells {
            assert_eq!(c.report.streams.len(), 2, "two tenants per point");
            assert_eq!(c.report.streams[0].class, CLASS_URGENT);
            assert_eq!(c.report.streams[1].class, CLASS_BULK);
            assert_eq!(c.report.streams[1].requests, 30);
            assert!(c.report.fairness > 0.0);
        }
        let rendered = render_qos_sweep("t", &cells, false);
        assert!(rendered.contains("latency-critical tenant p99"));
        assert!(rendered.contains("read_priority"));
        let csv = render_qos_sweep("t", &cells, true);
        assert!(csv.contains("iface,ways,sched,stream"));
    }

    #[test]
    fn observe_sweep_grid_shape_and_rendering() {
        let pool = ThreadPool::new(0);
        let spec = ObserveSweepSpec {
            ways: vec![2],
            ifaces: vec![InterfaceKind::Conv, InterfaceKind::Proposed],
            requests: 20,
            blocks_per_chip: 128,
            ..ObserveSweepSpec::default()
        };
        let cells = run_observe_sweep(&spec, &pool);
        assert_eq!(cells.len(), 2); // 2 ifaces x 1 way count
        for c in &cells {
            assert!(c.report.bandwidth_mbps > 0.0);
            let o = c.report.observe.as_ref().expect("observation was enabled");
            assert!(o.wall_ps > 0);
            // No timeline was requested; the accounting is still complete.
            assert!(o.trace_json.is_none());
            for r in &o.resources {
                assert_eq!(r.total_ps(), o.wall_ps, "{:?}", r);
            }
        }
        let rendered = render_observe_sweep("t", &cells, false);
        assert!(rendered.contains("stall attribution"));
        assert!(rendered.contains("PROPOSED"));
        let csv = render_observe_sweep("t", &cells, true);
        assert!(csv.contains("iface,ways,resource,busy_ps"));
    }

    #[test]
    fn map_sweep_injects_translation_traffic() {
        let pool = ThreadPool::new(0);
        // 1x2x128-block SLC geometry: 16,384 physical pages, 14,745
        // logical, 231 translation pages at 64 entries each.
        let spec = MapSweepSpec {
            channels: 1,
            ways: 2,
            blocks_per_chip: 128,
            entries_per_page: 64,
            requests: 120,
            cache_pages: vec![8, 512],
            locality: vec![(0.1, 0.9)],
            ..MapSweepSpec::default()
        };
        let cells = run_map_sweep(&spec, &pool);
        assert_eq!(cells.len(), 2);
        let starved = &cells[0].report;
        let resident = &cells[1].report;
        assert!(starved.map_misses > 0, "8-tpage cache must thrash");
        assert!(starved.map_pages_read > 0, "misses must become flash reads");
        // cache >= tpages warm-starts fully resident: no fill traffic.
        assert_eq!(resident.map_misses, 0);
        assert!(resident.map_hits > 0);
        assert!(
            resident.bandwidth_mbps >= starved.bandwidth_mbps,
            "translation traffic cannot speed the device up: {} < {}",
            resident.bandwidth_mbps,
            starved.bandwidth_mbps
        );
        let rendered = render_map_sweep("t", &cells, false);
        assert!(rendered.contains("cache_tpages"));
        let csv = render_map_sweep("t", &cells, true);
        assert!(csv.contains("cache_tpages,hot_frac"));
    }

    #[test]
    fn headline_mentions_all_cells() {
        let pool = ThreadPool::new(0);
        let cells = run_table3(30, &pool);
        let h = headline(&cells);
        assert!(h.contains("SLC read"));
        assert!(h.contains("MLC write"));
    }
}
