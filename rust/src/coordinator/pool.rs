//! A small scoped worker pool for running independent simulations in
//! parallel (tokio is unavailable offline; a CPU-bound DES sweep wants
//! plain threads anyway).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Fixed-size thread pool executing a batch of closures and collecting
/// results in submission order.
pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// `workers = 0` selects the available parallelism.
    pub fn new(workers: usize) -> ThreadPool {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            workers
        };
        ThreadPool { workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run all `jobs` across the pool; returns results in input order.
    ///
    /// Panics in jobs propagate (fail fast — a panicking simulation is a
    /// bug, not a condition to swallow).
    pub fn run_all<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let jobs: Vec<_> = jobs
            .into_iter()
            .map(|f| move |_state: &mut ()| f())
            .collect();
        self.run_all_with(jobs, || ())
    }

    /// Like [`run_all`](ThreadPool::run_all), but each worker thread owns
    /// one `state` value (built by `mk_state` on that worker) that is
    /// threaded through every job it executes. This is how sweeps reuse
    /// per-worker simulator state across sweep points: the state is a
    /// `SimWorkspace` and consecutive jobs on a worker retarget it instead
    /// of rebuilding channels/ways/chips per run.
    pub fn run_all_with<T, F, S, G>(&self, jobs: Vec<F>, mk_state: G) -> Vec<T>
    where
        T: Send,
        F: FnOnce(&mut S) -> T + Send,
        G: Fn() -> S + Sync,
    {
        let n = jobs.len();
        let queue: Arc<Mutex<Vec<(usize, F)>>> =
            Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        let mk_state = &mk_state;
        std::thread::scope(|s| {
            for _ in 0..self.workers.min(n.max(1)) {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                s.spawn(move || {
                    let mut state = mk_state();
                    loop {
                        let job = queue.lock().unwrap().pop();
                        match job {
                            Some((i, f)) => {
                                let r = f(&mut state);
                                if tx.send((i, r)).is_err() {
                                    return;
                                }
                            }
                            None => return,
                        }
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
            for (i, r) in rx {
                out[i] = Some(r);
            }
            out.into_iter()
                .map(|o| o.expect("worker died before completing job"))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_submission_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..32)
            .map(|i| move || {
                // Stagger to shuffle completion order.
                std::thread::sleep(std::time::Duration::from_millis((32 - i) % 5));
                i * 10
            })
            .collect();
        let out = pool.run_all(jobs);
        assert_eq!(out, (0..32).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn zero_workers_selects_parallelism() {
        let pool = ThreadPool::new(0);
        assert!(pool.workers() >= 1);
    }

    #[test]
    fn empty_batch() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.run_all(Vec::<fn() -> i32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_sequential() {
        let pool = ThreadPool::new(1);
        let out = pool.run_all((0..5).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }

    /// Stress: many jobs with deliberately uneven durations over worker
    /// state. Order must be preserved, every job must see exactly one
    /// worker-local state, and the per-worker run counts must add up.
    #[test]
    fn run_all_with_uneven_jobs_reuses_worker_state() {
        struct WorkerState {
            runs: u64,
        }
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..64u64)
            .map(|i| {
                move |st: &mut WorkerState| {
                    st.runs += 1;
                    // Uneven durations: some jobs ~20x longer than others,
                    // so fast workers steal more jobs (uneven reuse).
                    let spins = if i % 7 == 0 { 400_000 } else { 20_000 };
                    let mut acc = i;
                    for k in 0..spins {
                        acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k);
                    }
                    std::hint::black_box(acc);
                    (i * 3, st.runs)
                }
            })
            .collect();
        let out = pool.run_all_with(jobs, || WorkerState { runs: 0 });
        // Submission order preserved despite completion-order shuffling.
        for (i, &(v, runs)) in out.iter().enumerate() {
            assert_eq!(v, i as u64 * 3);
            assert!((1..=64).contains(&runs));
        }
        // Each job incremented exactly one worker's counter: within any
        // worker the observed `runs` values are 1..=k, so the number of
        // jobs observing `runs == 1` equals the number of workers used.
        let firsts = out.iter().filter(|&&(_, r)| r == 1).count();
        assert!((1..=4).contains(&firsts), "firsts={firsts}");
        // And state was actually reused: with 64 jobs on <= 4 workers,
        // some job must have seen runs >= 16.
        assert!(out.iter().any(|&(_, r)| r >= 16));
    }

    #[test]
    fn run_all_with_single_worker_threads_state_through_all_jobs() {
        let pool = ThreadPool::new(1);
        let jobs: Vec<_> = (0..10u64)
            .map(|_| move |st: &mut u64| {
                *st += 1;
                *st
            })
            .collect();
        let out = pool.run_all_with(jobs, || 0u64);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }
}
