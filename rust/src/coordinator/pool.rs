//! A small scoped worker pool for running independent simulations in
//! parallel (tokio is unavailable offline; a CPU-bound DES sweep wants
//! plain threads anyway).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Fixed-size thread pool executing a batch of closures and collecting
/// results in submission order.
pub struct ThreadPool {
    workers: usize,
}

impl ThreadPool {
    /// `workers = 0` selects the available parallelism.
    pub fn new(workers: usize) -> ThreadPool {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        } else {
            workers
        };
        ThreadPool { workers }
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run all `jobs` across the pool; returns results in input order.
    ///
    /// Panics in jobs propagate (fail fast — a panicking simulation is a
    /// bug, not a condition to swallow).
    pub fn run_all<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send,
        F: FnOnce() -> T + Send,
    {
        let n = jobs.len();
        let queue: Arc<Mutex<Vec<(usize, F)>>> =
            Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
        let (tx, rx) = mpsc::channel::<(usize, T)>();
        std::thread::scope(|s| {
            for _ in 0..self.workers.min(n.max(1)) {
                let queue = Arc::clone(&queue);
                let tx = tx.clone();
                s.spawn(move || loop {
                    let job = queue.lock().unwrap().pop();
                    match job {
                        Some((i, f)) => {
                            let r = f();
                            if tx.send((i, r)).is_err() {
                                return;
                            }
                        }
                        None => return,
                    }
                });
            }
            drop(tx);
            let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
            for (i, r) in rx {
                out[i] = Some(r);
            }
            out.into_iter()
                .map(|o| o.expect("worker died before completing job"))
                .collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_submission_order() {
        let pool = ThreadPool::new(4);
        let jobs: Vec<_> = (0..32)
            .map(|i| move || {
                // Stagger to shuffle completion order.
                std::thread::sleep(std::time::Duration::from_millis((32 - i) % 5));
                i * 10
            })
            .collect();
        let out = pool.run_all(jobs);
        assert_eq!(out, (0..32).map(|i| i * 10).collect::<Vec<_>>());
    }

    #[test]
    fn zero_workers_selects_parallelism() {
        let pool = ThreadPool::new(0);
        assert!(pool.workers() >= 1);
    }

    #[test]
    fn empty_batch() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.run_all(Vec::<fn() -> i32>::new());
        assert!(out.is_empty());
    }

    #[test]
    fn single_worker_sequential() {
        let pool = ThreadPool::new(1);
        let out = pool.run_all((0..5).map(|i| move || i).collect::<Vec<_>>());
        assert_eq!(out, vec![0, 1, 2, 3, 4]);
    }
}
