"""L2 model composition and AOT lowering checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.kernels.ref import PERF_COLS, TIMING_COLS


class TestModelShapes:
    def test_perf_model(self):
        pts = jnp.ones((aot.PERF_N, PERF_COLS), jnp.float32)
        (out,) = model.perf_model(pts)
        assert out.shape == (aot.PERF_N, 4)

    def test_timing_model_headroom_column(self):
        p = jnp.ones((aot.TIMING_N, TIMING_COLS), jnp.float32)
        p = p.at[:, 7].set(0.5)  # alpha
        (out,) = model.timing_model(p)
        assert out.shape == (aot.TIMING_N, 4)
        conv, prop, gain = out[:, 0], out[:, 2], out[:, 3]
        np.testing.assert_allclose(gain, conv / prop, rtol=1e-6)

    def test_mc_model(self):
        p = jnp.ones((aot.MC_N, TIMING_COLS), jnp.float32)
        z = jnp.zeros((aot.MC_S, 4), jnp.float32)
        sig = jnp.asarray([0.1, 0.05, 1.1], jnp.float32)
        (out,) = model.mc_model(p, z, sig)
        assert out.shape == (aot.MC_N, 3)


class TestAotLowering:
    def test_lowers_to_hlo_text(self):
        arts = aot.lower_all()
        assert set(arts) == {"perf.hlo.txt", "timing.hlo.txt", "mc.hlo.txt"}
        for name, text in arts.items():
            assert "HloModule" in text, f"{name} is not HLO text"
            assert "ENTRY" in text, f"{name} lacks an entry computation"
            # No Mosaic custom-calls: interpret=True must fully lower.
            assert "tpu_custom_call" not in text, f"{name} has TPU custom call"

    def test_manifest_mentions_every_artifact(self):
        m = aot.manifest()
        for name in ("perf.hlo.txt", "timing.hlo.txt", "mc.hlo.txt"):
            assert name in m


class TestLoweredNumerics:
    """The lowered (jit) path must equal the eager path — guards against
    lowering-order bugs before the artifact ships to Rust."""

    def test_perf_jit_equals_eager(self):
        rng = np.random.default_rng(3)
        from tests.test_kernels import random_perf_points

        pts = jnp.asarray(random_perf_points(aot.PERF_N, rng))
        eager = model.perf_model(pts)[0]
        jitted = jax.jit(model.perf_model)(pts)[0]
        np.testing.assert_allclose(eager, jitted, rtol=1e-6)
