"""Kernel-vs-reference correctness: the CORE build-time signal.

Every Pallas kernel must agree with its pure-jnp oracle; hypothesis sweeps
the parameter space (shapes are fixed by BlockSpec multiples, values vary).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bandwidth, montecarlo, timing
from compile.kernels.ref import (
    PERF_COLS,
    TIMING_COLS,
    montecarlo_ref,
    perf_ref,
    timing_ref,
)

RNG = np.random.default_rng(0xDD12)


def random_perf_points(n, rng=RNG):
    """Physically-plausible design points (strictly positive params)."""
    pts = np.empty((n, PERF_COLS), dtype=np.float32)
    pts[:, 0] = rng.uniform(1.0, 30.0, n)  # data_byte_ns
    pts[:, 1] = rng.uniform(100.0, 5000.0, n)  # cmd_ns
    pts[:, 2] = rng.uniform(0.0, 20000.0, n)  # ecc_ns
    pts[:, 3] = rng.uniform(0.0, 5000.0, n)  # status_ns
    pts[:, 4] = rng.uniform(10_000.0, 100_000.0, n)  # t_r_ns
    pts[:, 5] = rng.uniform(100_000.0, 1_000_000.0, n)  # t_prog_ns
    pts[:, 6] = rng.choice([2048.0, 4096.0, 8192.0], n)  # page
    pts[:, 7] = pts[:, 6] * rng.uniform(1.0, 1.1, n)  # transfer
    pts[:, 8] = rng.choice([1.0, 2.0, 4.0, 8.0, 16.0, 32.0], n)  # ways
    pts[:, 9] = rng.choice([1.0, 2.0, 4.0, 8.0], n)  # channels
    pts[:, 10] = rng.choice([150.0, 300.0, 600.0], n)  # sata
    pts[:, 11] = rng.uniform(10.0, 100.0, n)  # power mW
    return pts


def random_timing_params(n, rng=RNG):
    p = np.empty((n, TIMING_COLS), dtype=np.float32)
    p[:, 0] = rng.uniform(1.0, 15.0, n)  # t_out
    p[:, 1] = rng.uniform(0.5, 5.0, n)  # t_in
    p[:, 2] = rng.uniform(0.1, 1.0, n)  # t_s
    p[:, 3] = rng.uniform(0.01, 0.5, n)  # t_h
    p[:, 4] = rng.uniform(1.0, 8.0, n)  # t_diff
    p[:, 5] = rng.uniform(5.0, 40.0, n)  # t_rea
    p[:, 6] = rng.uniform(4.0, 20.0, n)  # t_byte
    p[:, 7] = rng.uniform(0.0, 0.5, n)  # alpha
    p[:, 8] = rng.uniform(1.0, 4.0, n)  # t_ios
    p[:, 9] = rng.uniform(1.0, 4.0, n)  # t_ioh
    return p


class TestPerfKernel:
    def test_matches_ref_bulk(self):
        pts = jnp.asarray(random_perf_points(1024))
        np.testing.assert_allclose(
            bandwidth.perf_grid(pts), perf_ref(pts), rtol=1e-6
        )

    def test_single_block(self):
        pts = jnp.asarray(random_perf_points(bandwidth.BLOCK_ROWS))
        np.testing.assert_allclose(
            bandwidth.perf_grid(pts), perf_ref(pts), rtol=1e-6
        )

    def test_rejects_bad_shapes(self):
        with pytest.raises(AssertionError):
            bandwidth.perf_grid(jnp.zeros((100, PERF_COLS), jnp.float32))
        with pytest.raises(AssertionError):
            bandwidth.perf_grid(jnp.zeros((256, 7), jnp.float32))

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1), blocks=st.integers(1, 4))
    def test_matches_ref_hypothesis(self, seed, blocks):
        rng = np.random.default_rng(seed)
        pts = jnp.asarray(random_perf_points(blocks * bandwidth.BLOCK_ROWS, rng))
        np.testing.assert_allclose(
            bandwidth.perf_grid(pts), perf_ref(pts), rtol=1e-5
        )

    def test_paper_anchor_slc_conv(self):
        """SLC CONV 1-way, the paper's calibration anchor (Table 3)."""
        pt = np.zeros((bandwidth.BLOCK_ROWS, PERF_COLS), np.float32)
        pt[:] = [
            20.0,  # data_byte (50 MHz SDR)
            2400.0,  # cmd (120 cycles)
            3500.0,  # ecc
            2040.0,  # status
            25_000.0,  # t_r
            215_000.0,  # t_prog
            2048.0,
            2112.0,
            1.0,
            1.0,
            300.0,
            22.5,
        ]
        out = np.asarray(bandwidth.perf_grid(jnp.asarray(pt)))[0]
        assert abs(out[0] - 27.8) < 0.5, f"read={out[0]}"  # paper: 27.78
        assert abs(out[1] - 7.72) < 0.15, f"write={out[1]}"  # paper: 7.77
        assert abs(out[2] - 22.5 / out[0]) < 1e-4  # energy identity

    def test_sata_cap_binds(self):
        pt = random_perf_points(bandwidth.BLOCK_ROWS)
        pt[:, 8] = 32  # many ways
        pt[:, 9] = 8  # many channels
        pt[:, 10] = 300.0
        out = np.asarray(bandwidth.perf_grid(jnp.asarray(pt)))
        assert (out[:, 0] <= 300.0 + 1e-3).all()


class TestTimingKernel:
    def test_matches_ref(self):
        p = jnp.asarray(random_timing_params(512))
        np.testing.assert_allclose(timing.timing_grid(p), timing_ref(p), rtol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def test_matches_ref_hypothesis(self, seed):
        rng = np.random.default_rng(seed)
        p = jnp.asarray(random_timing_params(timing.BLOCK_ROWS, rng))
        np.testing.assert_allclose(timing.timing_grid(p), timing_ref(p), rtol=1e-5)

    def test_paper_table2_values(self):
        """S5.2: CONV 19.81 ns, PROPOSED 12 ns at the Table 2 corner."""
        p = np.zeros((timing.BLOCK_ROWS, TIMING_COLS), np.float32)
        p[:] = [7.82, 1.65, 0.25, 0.02, 4.69, 20.0, 12.0, 0.5, 2.75, 2.75]
        tp = np.asarray(timing.timing_grid(jnp.asarray(p)))[0]
        assert abs(tp[0] - 19.81) < 0.01, f"conv={tp[0]}"
        assert abs(tp[2] - 12.0) < 1e-5, f"proposed={tp[2]}"
        # Operating frequencies per the paper's floor rule.
        assert np.floor(1000.0 / tp[0]) == 50
        assert np.floor(1000.0 / tp[2]) == 83

    def test_tbyte_floor(self):
        p = random_timing_params(timing.BLOCK_ROWS)
        p[:, 4] = 0.0  # perfect board
        p[:, 2] = 0.01
        p[:, 3] = 0.01
        tp = np.asarray(timing.timing_grid(jnp.asarray(p)))
        np.testing.assert_allclose(tp[:, 2], p[:, 6], rtol=1e-6)


class TestMonteCarloKernel:
    def _run(self, n=montecarlo.BLOCK_ROWS, s=512, seed=1, sigmas=(0.1, 0.05, 1.0)):
        rng = np.random.default_rng(seed)
        p = jnp.asarray(random_timing_params(n, rng))
        z = jnp.asarray(rng.standard_normal((s, 4)).astype(np.float32))
        sig = jnp.asarray(np.array(sigmas, np.float32))
        got = montecarlo.montecarlo_grid(p, z, sig)
        want = montecarlo_ref(p, z, sigmas[0], sigmas[1], sigmas[2])
        return np.asarray(got), np.asarray(want)

    def test_matches_ref(self):
        got, want = self._run()
        np.testing.assert_allclose(got, want, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**32 - 1))
    def test_matches_ref_hypothesis(self, seed):
        got, want = self._run(seed=seed)
        np.testing.assert_allclose(got, want, atol=1e-6)

    def test_probabilities_in_range(self):
        got, _ = self._run()
        assert (got >= 0.0).all() and (got <= 1.0).all()

    def test_conv_more_sensitive_at_table2(self):
        """The paper's PVT claim: at a tight margin, CONV violates more."""
        p = np.zeros((montecarlo.BLOCK_ROWS, TIMING_COLS), np.float32)
        p[:] = [7.82, 1.65, 0.25, 0.02, 4.69, 20.0, 12.0, 0.5, 2.75, 2.75]
        rng = np.random.default_rng(7)
        z = jnp.asarray(rng.standard_normal((4096, 4)).astype(np.float32))
        sig = jnp.asarray(np.array([0.10, 0.05, 1.0], np.float32))
        out = np.asarray(montecarlo.montecarlo_grid(jnp.asarray(p), z, sig))[0]
        # At margin 1.0 CONV sits exactly on its constraint -> ~half the
        # jittered corners violate; PROPOSED has t_BYTE slack -> none.
        assert out[0] > 0.2, f"conv={out[0]}"
        assert out[2] < 0.05, f"proposed={out[2]}"

    def test_zero_sigma_no_violations_with_margin(self):
        got, _ = self._run(sigmas=(0.0, 0.0, 1.001))
        assert (got == 0.0).all()
