"""Layer-2 JAX model: the analytic SSD design-space model.

Composes the Layer-1 Pallas kernels into the entry points that aot.py
lowers to HLO text for the Rust runtime:

* ``perf_model``   — design grid [N, 12] -> [N, 4] (read/write BW, energy)
* ``timing_model`` — Table 2 corners [N, 10] -> [N, 4]
  (t_P,min x 3 interfaces + CONV-vs-PROPOSED frequency headroom)
* ``mc_model``     — PVT Monte Carlo [N, 10] x [S, 4] -> [N, 3]

Python runs ONCE at build time (`make artifacts`); the Rust coordinator
executes the lowered HLO via PJRT on its DSE hot path.
"""

import jax.numpy as jnp

from compile.kernels.bandwidth import perf_grid
from compile.kernels.montecarlo import montecarlo_grid
from compile.kernels.timing import timing_grid


def perf_model(points):
    """Bandwidth/energy over a design grid (see ref.PERF_COLS)."""
    return (perf_grid(points),)


def timing_model(params):
    """t_P,min per interface plus the PROPOSED-over-CONV frequency gain.

    Returns [N, 4]: (conv, sync_only, proposed, conv/proposed ratio). The
    ratio column is the headroom the DDR design buys at each corner — the
    quantity DESIGN.md's A1/A2 ablations sweep.
    """
    tp = timing_grid(params)
    gain = tp[:, 0] / tp[:, 2]
    return (jnp.concatenate([tp, gain[:, None]], axis=-1),)


def mc_model(params, z, sigmas):
    """PVT violation probabilities (see kernels/montecarlo.py)."""
    return (montecarlo_grid(params, z, sigmas),)
