"""AOT lowering: JAX/Pallas model -> HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax >=
0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` crate) rejects; the text parser reassigns
ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).

Artifacts:
    perf.hlo.txt    f32[PERF_N, 12]             -> (f32[PERF_N, 4],)
    timing.hlo.txt  f32[TIMING_N, 10]           -> (f32[TIMING_N, 4],)
    mc.hlo.txt      f32[MC_N,10] f32[MC_S,4] f32[3] -> (f32[MC_N, 3],)
    manifest.txt    shape/layout contract consumed by rust/src/runtime
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.ref import PERF_COLS, TIMING_COLS

# Fixed grid sizes — the Rust runtime pads batches up to these.
PERF_N = 4096
TIMING_N = 1024
MC_N = 256
MC_S = 2048


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned on parse)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all():
    f32 = jnp.float32
    spec = jax.ShapeDtypeStruct
    return {
        "perf.hlo.txt": to_hlo_text(
            jax.jit(model.perf_model).lower(spec((PERF_N, PERF_COLS), f32))
        ),
        "timing.hlo.txt": to_hlo_text(
            jax.jit(model.timing_model).lower(spec((TIMING_N, TIMING_COLS), f32))
        ),
        "mc.hlo.txt": to_hlo_text(
            jax.jit(model.mc_model).lower(
                spec((MC_N, TIMING_COLS), f32),
                spec((MC_S, 4), f32),
                spec((3,), f32),
            )
        ),
    }


def manifest() -> str:
    return "\n".join(
        [
            "# ddrnand AOT artifact manifest (shapes are f32, row-major)",
            f"perf.hlo.txt in={PERF_N}x{PERF_COLS} out={PERF_N}x4",
            f"timing.hlo.txt in={TIMING_N}x{TIMING_COLS} out={TIMING_N}x4",
            f"mc.hlo.txt in={MC_N}x{TIMING_COLS},{MC_S}x4,3 out={MC_N}x3",
            "",
        ]
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--out", default=None, help="legacy single-file alias (writes perf)")
    args = ap.parse_args()

    out_dir = args.out_dir
    if args.out is not None:
        out_dir = os.path.dirname(args.out) or "."
    os.makedirs(out_dir, exist_ok=True)

    artifacts = lower_all()
    for name, text in artifacts.items():
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>9} chars to {path}")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write(manifest())
    print(f"wrote manifest to {out_dir}/manifest.txt")


if __name__ == "__main__":
    main()
