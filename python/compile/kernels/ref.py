"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth at build time (pytest compares every
kernel against them), and they mirror ``rust/src/analytic/mod.rs`` formula
for formula — the Rust integration test ``analytic_vs_hlo`` closes the loop
by comparing the AOT artifact against the Rust mirror.

Column layouts are shared by kernels, refs, aot.py and the Rust runtime:

``PERF_COLS`` (design-point matrix, [N, 12])::

    0 data_byte_ns   per-byte bus data time
    1 cmd_ns         command+address+controller overhead phase
    2 ecc_ns         ECC page latency
    3 status_ns      post-program status phase
    4 t_r_ns         array read fetch (t_R)
    5 t_prog_ns      array program (t_PROG)
    6 page_bytes     main page bytes
    7 transfer_bytes page+spare bytes moved on the bus
    8 ways           way-interleaving degree
    9 channels       channel count
    10 sata_mbps     host link cap
    11 controller_mw controller power for the energy metric

``TIMING_COLS`` ([N, 10])::

    0 t_out_ns  1 t_in_ns  2 t_s_ns  3 t_h_ns  4 t_diff_ns
    5 t_rea_ns  6 t_byte_ns  7 alpha  8 t_ios_ns  9 t_ioh_ns
"""

import jax.numpy as jnp

PERF_COLS = 12
TIMING_COLS = 10
PERF_OUTS = 4  # read_bw, write_bw, read_nj_per_b, write_nj_per_b
TIMING_OUTS = 3  # tp_min for CONV, SYNC_ONLY, PROPOSED


def perf_ref(points):
    """Steady-state bandwidth + energy model. points: [N, 12] -> [N, 4]."""
    data_byte = points[:, 0]
    cmd = points[:, 1]
    ecc = points[:, 2]
    status = points[:, 3]
    t_r = points[:, 4]
    t_prog = points[:, 5]
    page = points[:, 6]
    xfer = points[:, 7]
    ways = points[:, 8]
    channels = points[:, 9]
    sata = points[:, 10]
    power = points[:, 11]

    o_r = cmd + xfer * data_byte + ecc
    read_period = jnp.maximum(o_r, (o_r + t_r) / ways)
    read_bw = jnp.minimum(page / read_period * 1e3 * channels, sata)

    o_w = o_r + status
    write_period = jnp.maximum(o_w, (o_w + t_prog) / ways)
    write_bw = jnp.minimum(page / write_period * 1e3 * channels, sata)

    return jnp.stack(
        [read_bw, write_bw, power / read_bw, power / write_bw], axis=-1
    )


def timing_ref(params):
    """Minimum clock periods, Eqs. (6)/(9) + SYNC_ONLY. [N, 10] -> [N, 3]."""
    t_out = params[:, 0]
    t_in = params[:, 1]
    t_s = params[:, 2]
    t_h = params[:, 3]
    t_diff = params[:, 4]
    t_rea = params[:, 5]
    t_byte = params[:, 6]
    alpha = params[:, 7]

    conv = jnp.maximum((t_out + t_rea + t_in + t_s) / (1.0 + alpha), t_byte)
    sync = jnp.maximum(t_s + t_h + t_diff, t_byte)
    prop = jnp.maximum(2.0 * (t_s + t_h + t_diff), t_byte)
    return jnp.stack([conv, sync, prop], axis=-1)


def operating_freq_mhz(tp_min_ns):
    """The paper's frequency rule (S5.2): floor to whole MHz."""
    return jnp.floor(1000.0 / tp_min_ns)


def montecarlo_ref(params, z, chip_sigma, board_sigma, margin):
    """Setup-violation probability per design point under PVT jitter.

    params: [N, 10] (TIMING_COLS); z: [S, 4] standard normals jittering
    (t_out, t_in, t_rea, t_diff); margin: run each interface at its nominal
    t_P,min x margin. Returns [N, 3] violation fractions.
    """
    t_out = params[:, 0:1] * (1.0 + chip_sigma * z[None, :, 0])  # [N, S]
    t_in = params[:, 1:2] * (1.0 + chip_sigma * z[None, :, 1])
    t_rea = params[:, 5:6] * (1.0 + chip_sigma * z[None, :, 2])
    t_diff = params[:, 4:5] * (1.0 + board_sigma * z[None, :, 3])
    t_s = params[:, 2:3]
    t_h = params[:, 3:4]
    alpha = params[:, 7:8]

    tp = timing_ref(params) * margin  # [N, 3]

    conv_ok = t_out + t_rea + t_in + t_s <= (1.0 + alpha) * tp[:, 0:1]
    sync_ok = t_s + t_h + t_diff <= tp[:, 1:2]
    prop_ok = 2.0 * (t_s + t_h + t_diff) <= tp[:, 2:3]

    def viol(ok):
        return 1.0 - jnp.mean(ok.astype(jnp.float32), axis=1)

    return jnp.stack([viol(conv_ok), viol(sync_ok), viol(prop_ok)], axis=-1)
