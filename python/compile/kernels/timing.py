"""Pallas kernel: interface minimum-clock-period equations (Eqs. 6/8/9).

Evaluates t_P,min for CONV / SYNC_ONLY / PROPOSED over a grid of Table 2
parameter corners (used by the DSE for alpha / t_BYTE / t_DIFF sweeps).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import TIMING_COLS, TIMING_OUTS

BLOCK_ROWS = 256


def _timing_kernel(params_ref, out_ref):
    p = params_ref[...]
    t_out = p[:, 0]
    t_in = p[:, 1]
    t_s = p[:, 2]
    t_h = p[:, 3]
    t_diff = p[:, 4]
    t_rea = p[:, 5]
    t_byte = p[:, 6]
    alpha = p[:, 7]

    conv = jnp.maximum((t_out + t_rea + t_in + t_s) / (1.0 + alpha), t_byte)
    sync = jnp.maximum(t_s + t_h + t_diff, t_byte)
    prop = jnp.maximum(2.0 * (t_s + t_h + t_diff), t_byte)
    out_ref[...] = jnp.stack([conv, sync, prop], axis=-1)


def timing_grid(params):
    """[N, 10] Table 2 corners -> [N, 3] t_P,min in ns."""
    n, cols = params.shape
    assert cols == TIMING_COLS, f"want {TIMING_COLS} columns, got {cols}"
    assert n % BLOCK_ROWS == 0, f"N={n} must be a multiple of {BLOCK_ROWS}"
    return pl.pallas_call(
        _timing_kernel,
        grid=(n // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, TIMING_COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, TIMING_OUTS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, TIMING_OUTS), params.dtype),
        interpret=True,
    )(params)
