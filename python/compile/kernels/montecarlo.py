"""Pallas kernel: PVT Monte Carlo setup-violation analysis.

For each design point, jitter the read-path delays with pre-drawn standard
normals and count setup violations at a clock period of nominal t_P,min x
margin. This quantifies the paper's PVT-desensitization argument (S2.3.3 /
ref. [23]): CONV accumulates three varying on-chip paths, the DVS designs
only the board skew.

The sample axis is the inner loop: each kernel block loads its rows once and
streams all S samples against them (S x N compare/accumulate — the compute-
dense kernel of the three).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import TIMING_COLS, TIMING_OUTS

BLOCK_ROWS = 64


def _mc_kernel(params_ref, z_ref, sig_ref, out_ref):
    p = params_ref[...]  # [B, 10]
    z = z_ref[...]  # [S, 4]
    chip_sigma = sig_ref[0]
    board_sigma = sig_ref[1]
    margin = sig_ref[2]

    t_s = p[:, 2:3]
    t_h = p[:, 3:4]
    alpha = p[:, 7:8]

    # Nominal operating points (x margin).
    conv_tp = jnp.maximum((p[:, 0] + p[:, 5] + p[:, 1] + p[:, 2]) / (1.0 + p[:, 7]), p[:, 6])
    sync_tp = jnp.maximum(p[:, 2] + p[:, 3] + p[:, 4], p[:, 6])
    prop_tp = jnp.maximum(2.0 * (p[:, 2] + p[:, 3] + p[:, 4]), p[:, 6])

    # Jittered paths: [B, S].
    t_out = p[:, 0:1] * (1.0 + chip_sigma * z[None, :, 0])
    t_in = p[:, 1:2] * (1.0 + chip_sigma * z[None, :, 1])
    t_rea = p[:, 5:6] * (1.0 + chip_sigma * z[None, :, 2])
    t_diff = p[:, 4:5] * (1.0 + board_sigma * z[None, :, 3])

    conv_ok = t_out + t_rea + t_in + t_s <= (1.0 + alpha) * (conv_tp * margin)[:, None]
    sync_ok = t_s + t_h + t_diff <= (sync_tp * margin)[:, None]
    prop_ok = 2.0 * (t_s + t_h + t_diff) <= (prop_tp * margin)[:, None]

    viol = lambda ok: 1.0 - jnp.mean(ok.astype(jnp.float32), axis=1)
    out_ref[...] = jnp.stack([viol(conv_ok), viol(sync_ok), viol(prop_ok)], axis=-1)


def montecarlo_grid(params, z, sigmas):
    """params: [N, 10]; z: [S, 4] standard normals; sigmas: [3] =
    (chip_sigma, board_sigma, margin). Returns [N, 3] violation fractions."""
    n, cols = params.shape
    s, zc = z.shape
    assert cols == TIMING_COLS and zc == 4
    assert n % BLOCK_ROWS == 0, f"N={n} must be a multiple of {BLOCK_ROWS}"
    return pl.pallas_call(
        _mc_kernel,
        grid=(n // BLOCK_ROWS,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, TIMING_COLS), lambda i: (i, 0)),
            pl.BlockSpec((s, 4), lambda i: (0, 0)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, TIMING_OUTS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, TIMING_OUTS), jnp.float32),
        interpret=True,
    )(params, z, sigmas)
