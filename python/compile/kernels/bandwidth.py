"""Pallas kernel: steady-state SSD bandwidth + energy over a design grid.

The hot spot of the analytic model: evaluate the way-interleaving saturation
equations for every design point in a (possibly large) grid. Elementwise
over rows, so the TPU mapping is pure VPU work; ``BlockSpec`` tiles rows
into VMEM-sized blocks (see DESIGN.md SHardware-Adaptation).

Runs with ``interpret=True`` so the lowered HLO executes on any PJRT
backend, including the Rust CPU client.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import PERF_COLS, PERF_OUTS

# Rows per VMEM block: 256 rows x 12 cols x 4 B = 12 KiB in, 4 KiB out.
BLOCK_ROWS = 256


def _perf_kernel(pts_ref, out_ref):
    p = pts_ref[...]  # [B, 12]
    data_byte = p[:, 0]
    cmd = p[:, 1]
    ecc = p[:, 2]
    status = p[:, 3]
    t_r = p[:, 4]
    t_prog = p[:, 5]
    page = p[:, 6]
    xfer = p[:, 7]
    ways = p[:, 8]
    channels = p[:, 9]
    sata = p[:, 10]
    power = p[:, 11]

    o_r = cmd + xfer * data_byte + ecc
    read_period = jnp.maximum(o_r, (o_r + t_r) / ways)
    read_bw = jnp.minimum(page / read_period * 1e3 * channels, sata)

    o_w = o_r + status
    write_period = jnp.maximum(o_w, (o_w + t_prog) / ways)
    write_bw = jnp.minimum(page / write_period * 1e3 * channels, sata)

    out_ref[...] = jnp.stack(
        [read_bw, write_bw, power / read_bw, power / write_bw], axis=-1
    )


def perf_grid(points):
    """Evaluate the perf model for a [N, 12] grid; N must be a multiple of
    BLOCK_ROWS (aot.py and the Rust runtime pad)."""
    n, cols = points.shape
    assert cols == PERF_COLS, f"want {PERF_COLS} columns, got {cols}"
    assert n % BLOCK_ROWS == 0, f"N={n} must be a multiple of {BLOCK_ROWS}"
    return pl.pallas_call(
        _perf_kernel,
        grid=(n // BLOCK_ROWS,),
        in_specs=[pl.BlockSpec((BLOCK_ROWS, PERF_COLS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((BLOCK_ROWS, PERF_OUTS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, PERF_OUTS), points.dtype),
        interpret=True,
    )(points)
