use std::collections::HashMap;

pub struct Tracker {
    seen: HashMap<u64, u64>,
}

impl Tracker {
    pub fn lookup(&self, k: u64) -> Option<u64> {
        self.seen.get(&k).copied()
    }

    pub fn count(&self) -> usize {
        self.seen.len()
    }

    pub fn stamp(&self) -> u64 {
        // simlint: allow(nondet, "harness-only wall clock, never sim state")
        let t0 = std::time::Instant::now();
        let _ = t0;
        7
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_may_use_wall_clock() {
        let t0 = std::time::Instant::now();
        let tr = Tracker { seen: HashMap::new() };
        for (k, v) in &tr.seen {
            let _ = (k, v, t0);
        }
    }
}
