pub fn reschedule(q: &mut EventQueue, ev: &mut Event, when: u64) {
    ev.at = when;
    q.push(ev.clone());
}
