pub fn reschedule(q: &mut EventQueue, ev: &mut Event, when: u64) {
    ev.at = when;
    q.push(ev.clone());
}

pub fn forge(when: Ps, src: u32) -> EventKey {
    EventKey { at: when, src, seq: 0 }
}
