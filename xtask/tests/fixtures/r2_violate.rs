pub fn degrade(t_busy_ps: u64) -> u64 {
    let scaled = (t_busy_ps as f64) * 1.07;
    scaled as u64
}

pub fn pad(now_ps: u64) -> u64 {
    now_ps + 1_500
}

pub fn drift(deadline: u64) -> u64 {
    deadline + (0.5_f64 * 3.0) as u64
}
