pub fn stamp() -> (u64, u64) {
    // simlint: allow(wall-clock, "unknown rule name")
    let t0 = std::time::Instant::now();
    // simlint: allow(nondet)
    let t1 = std::time::Instant::now();
    (t0.elapsed().as_nanos() as u64, t1.elapsed().as_nanos() as u64)
}
