use std::collections::HashMap;
use std::time::Instant;

pub struct Tracker {
    seen: HashMap<u64, u64>,
}

impl Tracker {
    pub fn snapshot(&self) -> Vec<(u64, u64)> {
        let t0 = Instant::now();
        let _ = t0;
        let mut out = Vec::new();
        for (k, v) in &self.seen {
            out.push((*k, *v));
        }
        out
    }

    pub fn checksum(&self) -> u64 {
        std::thread::sleep(std::time::Duration::from_millis(1));
        self.seen.values().sum()
    }
}
