pub fn load(text: &str) -> u32 {
    text.trim().parse().unwrap()
}

pub fn validate(x: u32) -> u32 {
    if x == 0 {
        panic!("zero");
    }
    x.checked_mul(2).expect("overflow")
}
