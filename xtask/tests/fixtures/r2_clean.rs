pub fn advance(now_ps: u64, step_ps: u64) -> u64 {
    now_ps + step_ps
}

pub fn span_ns(t: crate::util::time::Ps) -> f64 {
    t.as_ns_f64()
}

pub fn blend(a: f64, b: f64) -> f64 {
    0.5 * a + 0.5 * b
}
