pub fn load(text: &str) -> Result<u32, String> {
    text.trim().parse().map_err(|e| format!("bad count: {e}"))
}

pub fn validate(x: u32) -> Result<u32, String> {
    x.checked_mul(2).ok_or_else(|| "overflow".to_string())
}
