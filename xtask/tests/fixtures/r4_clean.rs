pub fn reschedule(sched: &mut Scheduler, cmd: Cmd, delay_ps: u64) {
    let when = sched.after(delay_ps);
    sched.send_at(when, cmd);
}
