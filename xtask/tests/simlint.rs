//! Golden-diagnostic tests for simlint fixtures, plus the self-check that
//! the real `rust/src` tree lints clean with the pinned allow count.
//!
//! The fixture files live in `tests/fixtures/` — they are lexed by the
//! linter, never compiled, so each can hold exactly the violation shape a
//! rule must catch (or the clean idiom it must not).

use std::path::Path;

use xtask::report::validate_report_json;
use xtask::rules::lint_source;

fn fixture(name: &str) -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

/// Violations rendered `rule|line|message`, in the linter's sorted order.
fn diags(rel: &str, name: &str) -> Vec<String> {
    lint_source(rel, &fixture(name))
        .violations
        .into_iter()
        .map(|v| format!("{}|{}|{}", v.rule, v.line, v.msg))
        .collect()
}

#[test]
fn r1_violations_get_exact_diagnostics() {
    let want = [
        "nondet|10|wall-clock `Instant::now` in simulator source",
        "nondet|13|for-loop over hash collection `seen` (order is nondeterministic)",
        "nondet|20|`thread::sleep` in simulator source",
        "nondet|21|iteration over hash collection `seen.values()` (order is nondeterministic)",
    ];
    assert_eq!(diags("controller/fixture.rs", "r1_violate.rs"), want);
}

#[test]
fn r1_clean_passes_with_one_allow() {
    let fl = lint_source("controller/fixture.rs", &fixture("r1_clean.rs"));
    assert!(fl.violations.is_empty(), "unexpected: {:?}", fl.violations);
    assert!(fl.malformed.is_empty());
    assert_eq!(fl.allows.len(), 1);
    assert_eq!(fl.allows[0].rule, "nondet");
    assert_eq!(fl.allows[0].comment_line, 17);
    assert_eq!(fl.allows[0].target_line, 18);
}

#[test]
fn r2_violations_only_inside_timing_scope() {
    let want = [
        "float-on-time|2|float cast on a time-typed expression",
        "float-on-time|11|float literal in arithmetic with a time-typed value",
    ];
    assert_eq!(diags("sim/fixture.rs", "r2_violate.rs"), want);
    // Same content outside the scoped modules: report code may use floats.
    assert_eq!(diags("report/fixture.rs", "r2_violate.rs"), Vec::<String>::new());
}

#[test]
fn r2_clean_idioms_pass_in_scope() {
    assert_eq!(diags("sim/fixture.rs", "r2_clean.rs"), Vec::<String>::new());
}

#[test]
fn r3_scope_is_config_dir_plus_validate_bodies() {
    let want = [
        "panic-in-config|2|`.unwrap()` in a config-load path (return an error instead)",
        "panic-in-config|7|`panic!` in a config-load path (return an error instead)",
        "panic-in-config|9|`.expect()` in a config-load path (return an error instead)",
    ];
    assert_eq!(diags("config/fixture.rs", "r3_violate.rs"), want);
    // Outside config/, only the `validate` body is in scope: the
    // `.unwrap()` in `load` (line 2) is exempt.
    assert_eq!(diags("report/fixture.rs", "r3_violate.rs"), &want[1..]);
}

#[test]
fn r3_clean_error_paths_pass() {
    assert_eq!(diags("config/fixture.rs", "r3_clean.rs"), Vec::<String>::new());
}

#[test]
fn r4_calendar_discipline_outside_sim() {
    let want = [
        "calendar-discipline|1|direct use of `EventQueue` outside sim/ (schedule via Scheduler/Emit)",
        "calendar-discipline|2|direct mutation of event time field `.at`",
        "calendar-discipline|7|struct-literal construction of `EventKey` outside sim/ (keys are minted by the engine)",
    ];
    assert_eq!(diags("controller/fixture.rs", "r4_violate.rs"), want);
    // sim/ owns the calendar: the identical content is legal there.
    assert_eq!(diags("sim/fixture.rs", "r4_violate.rs"), Vec::<String>::new());
}

#[test]
fn r4_clean_scheduler_idiom_passes() {
    assert_eq!(diags("controller/fixture.rs", "r4_clean.rs"), Vec::<String>::new());
}

#[test]
fn malformed_allows_are_counted_and_do_not_suppress() {
    let fl = lint_source("controller/fixture.rs", &fixture("allow_malformed.rs"));
    assert_eq!(fl.malformed, vec![2, 4]);
    assert!(fl.allows.is_empty());
    let lines: Vec<u32> = fl.violations.iter().map(|v| v.line).collect();
    assert_eq!(lines, vec![3, 5]);
}

/// The linter's reason to exist: the shipped tree must be clean, and the
/// allow count is pinned so a new escape hatch shows up in review as a
/// deliberate edit to this number.
#[test]
fn real_tree_lints_clean_with_pinned_allows() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../rust/src");
    let rep = xtask::lint_tree(&root).expect("walk rust/src");
    assert!(
        rep.files_scanned >= 50,
        "expected a full tree walk, scanned only {}",
        rep.files_scanned
    );
    let rendered: Vec<String> = rep
        .violations
        .iter()
        .map(|v| format!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg))
        .collect();
    assert!(rendered.is_empty(), "tree has violations:\n{}", rendered.join("\n"));
    assert!(rep.malformed.is_empty(), "malformed simlint comments: {:?}", rep.malformed);
    assert_eq!(
        rep.allows.len(),
        5,
        "allow count drifted — if deliberate, update the pin; allows: {:?}",
        rep.allows
    );
    // The machine-readable report round-trips through the repo's pinned
    // JSON dialect.
    validate_report_json(&rep.to_json()).expect("report validates");
}
