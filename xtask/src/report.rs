//! Machine-readable simlint report (`ddrnand-simlint-v1`).
//!
//! The writer is deliberately timestamp-free: a determinism linter should
//! itself produce byte-identical output for an unchanged tree, so the
//! report can be diffed across CI runs. The validator parses the emitted
//! JSON with `ddrnand::bench::json` — the same hand-rolled parser that
//! gates `BENCH_engine.json` and the observer timelines — so all the
//! repo's machine-readable artifacts share one pinned JSON dialect.

use ddrnand::bench::json::{self, Value};

use crate::scan::RULES;

/// The pinned schema tag checked by [`validate_report_json`] and CI.
pub const SCHEMA: &str = "ddrnand-simlint-v1";

/// One unsuppressed violation, with its file attached.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReportViolation {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// One `// simlint: allow(...)` site, with its file attached.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ReportAllow {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub reason: String,
}

/// Aggregated lint result for a whole tree.
#[derive(Debug, Default)]
pub struct Report {
    pub root: String,
    pub files_scanned: usize,
    pub violations: Vec<ReportViolation>,
    pub allows: Vec<ReportAllow>,
    /// (file, line) of malformed `simlint:` comments.
    pub malformed: Vec<(String, u32)>,
}

impl Report {
    /// Exit status the CLI should use: clean trees exit 0; violations or
    /// malformed allow comments exit 1.
    pub fn is_clean(&self) -> bool {
        self.violations.is_empty() && self.malformed.is_empty()
    }

    /// Serialize to the pinned `ddrnand-simlint-v1` JSON (deterministic:
    /// key order fixed, entries in sorted file walk order, no timestamp).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(1024);
        s.push_str("{\n");
        s.push_str(&format!("  \"schema\": \"{}\",\n", SCHEMA));
        s.push_str(&format!("  \"root\": {},\n", quote(&self.root)));
        s.push_str(&format!("  \"files_scanned\": {},\n", self.files_scanned));
        s.push_str("  \"violations\": [");
        for (i, v) in self.violations.iter().enumerate() {
            push_sep(&mut s, i);
            s.push_str(&format!(
                "    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"message\": {}}}",
                quote(&v.file),
                v.line,
                quote(v.rule),
                quote(&v.msg)
            ));
        }
        close_list(&mut s, self.violations.len());
        s.push_str("  \"allows\": [");
        for (i, a) in self.allows.iter().enumerate() {
            push_sep(&mut s, i);
            s.push_str(&format!(
                "    {{\"file\": {}, \"line\": {}, \"rule\": {}, \"reason\": {}}}",
                quote(&a.file),
                a.line,
                quote(&a.rule),
                quote(&a.reason)
            ));
        }
        close_list(&mut s, self.allows.len());
        s.push_str("  \"malformed\": [");
        for (i, (file, line)) in self.malformed.iter().enumerate() {
            push_sep(&mut s, i);
            s.push_str(&format!("    {{\"file\": {}, \"line\": {}}}", quote(file), line));
        }
        close_list(&mut s, self.malformed.len());
        s.push_str(&format!(
            "  \"counts\": {{\"violations\": {}, \"allows\": {}, \"malformed\": {}}}\n",
            self.violations.len(),
            self.allows.len(),
            self.malformed.len()
        ));
        s.push('}');
        s.push('\n');
        s
    }
}

fn push_sep(s: &mut String, i: usize) {
    if i == 0 {
        s.push('\n');
    } else {
        s.push_str(",\n");
    }
}

fn close_list(s: &mut String, len: usize) {
    if len > 0 {
        s.push_str("\n  ");
    }
    s.push_str("],\n");
}

/// JSON string escaping matching what `bench::json` can parse back.
fn quote(raw: &str) -> String {
    let mut out = String::with_capacity(raw.len() + 2);
    out.push('"');
    for c in raw.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Validate a serialized report: parseable by the repo's pinned JSON
/// dialect, right schema tag, counts consistent with the arrays, and
/// every violation/allow naming a known rule.
pub fn validate_report_json(text: &str) -> Result<(), String> {
    let v = json::parse(text)?;
    let obj = v.as_object().ok_or("report root must be an object")?;

    match get(obj, "schema")? {
        Value::Str(s) if s == SCHEMA => {}
        other => return Err(format!("bad schema tag: {other:?}")),
    }
    let files_scanned = as_count(get(obj, "files_scanned")?, "files_scanned")?;
    if files_scanned == 0 {
        return Err("files_scanned is 0 — lint root is wrong".to_string());
    }

    let violations = get_arr(obj, "violations")?;
    for item in violations {
        check_entry(item, &["file", "line", "rule", "message"])?;
    }
    let allows = get_arr(obj, "allows")?;
    for item in allows {
        check_entry(item, &["file", "line", "rule", "reason"])?;
    }
    let malformed = get_arr(obj, "malformed")?;
    for item in malformed {
        check_entry(item, &["file", "line"])?;
    }

    let counts_val = get(obj, "counts")?;
    let counts = counts_val.as_object().ok_or("`counts` must be an object")?;
    if get_count(counts, "violations")? != violations.len()
        || get_count(counts, "allows")? != allows.len()
        || get_count(counts, "malformed")? != malformed.len()
    {
        return Err("counts do not match array lengths".to_string());
    }
    Ok(())
}

fn get<'a>(obj: &'a [(String, Value)], key: &str) -> Result<&'a Value, String> {
    obj.iter()
        .find(|(k, _)| k == key)
        .map(|(_, v)| v)
        .ok_or_else(|| format!("missing key `{key}`"))
}

fn get_arr<'a>(obj: &'a [(String, Value)], key: &str) -> Result<&'a [Value], String> {
    match get(obj, key)? {
        Value::Array(items) => Ok(items),
        _ => Err(format!("`{key}` must be an array")),
    }
}

fn get_count(obj: &[(String, Value)], key: &str) -> Result<usize, String> {
    as_count(get(obj, key)?, key)
}

fn as_count(v: &Value, key: &str) -> Result<usize, String> {
    match v {
        Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as usize),
        _ => Err(format!("`{key}` must be a non-negative integer")),
    }
}

/// Check one array entry: object shape, required keys, `line` a positive
/// integer, any `rule` drawn from the known rule set.
fn check_entry(item: &Value, keys: &[&str]) -> Result<(), String> {
    let obj = item.as_object().ok_or("array entry must be an object")?;
    for key in keys {
        let val = obj
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v)
            .ok_or_else(|| format!("entry missing key `{key}`"))?;
        match (*key, val) {
            ("line", Value::Num(n)) if *n >= 1.0 && n.fract() == 0.0 => {}
            ("line", _) => return Err("`line` must be a positive integer".to_string()),
            ("rule", Value::Str(r)) if RULES.contains(&r.as_str()) => {}
            ("rule", other) => return Err(format!("unknown rule {other:?}")),
            (_, Value::Str(_)) => {}
            (k, _) => return Err(format!("`{k}` must be a string")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Report {
        Report {
            root: "rust/src".to_string(),
            files_scanned: 2,
            violations: vec![ReportViolation {
                file: "sim/engine.rs".to_string(),
                line: 7,
                rule: "float-on-time",
                msg: "float cast on a time-typed expression".to_string(),
            }],
            allows: vec![ReportAllow {
                file: "bench.rs".to_string(),
                line: 44,
                rule: "nondet".to_string(),
                reason: "wall clock is the measurand".to_string(),
            }],
            malformed: vec![("iface/bus.rs".to_string(), 3)],
        }
    }

    #[test]
    fn report_round_trips_through_pinned_parser() {
        let text = sample().to_json();
        validate_report_json(&text).expect("sample report must validate");
    }

    #[test]
    fn empty_report_validates() {
        let r = Report {
            root: "rust/src".to_string(),
            files_scanned: 1,
            ..Report::default()
        };
        validate_report_json(&r.to_json()).expect("empty report must validate");
    }

    #[test]
    fn tampered_counts_are_rejected() {
        let text = sample().to_json();
        let bad = text.replace("\"violations\": 1", "\"violations\": 2");
        assert!(validate_report_json(&bad).is_err());
    }

    #[test]
    fn unknown_rule_is_rejected() {
        let text = sample().to_json().replace("float-on-time", "bogus-rule");
        assert!(validate_report_json(&text).is_err());
    }

    #[test]
    fn zero_files_scanned_is_rejected() {
        let r = Report {
            root: "rust/src".to_string(),
            files_scanned: 0,
            ..Report::default()
        };
        assert!(validate_report_json(&r.to_json()).is_err());
    }

    #[test]
    fn escaping_survives_quotes_and_newlines() {
        let mut r = sample();
        r.allows[0].reason = "say \"hi\"\nand a \\ backslash\ttab".to_string();
        validate_report_json(&r.to_json()).expect("escaped report must validate");
    }
}
