//! Token-level scanner for simlint.
//!
//! Hand-rolled in the same spirit as `ddrnand::bench::json`: no external
//! dependencies, a small surface, and deterministic output. The scanner
//! strips comments and string literals (so rule patterns never match inside
//! them), distinguishes float from integer literals, captures
//! `// simlint: allow(<rule>, "<reason>")` escape hatches, and drops
//! `#[cfg(test)]` / `#[test]` items so the rules only see shipping code.

/// Rules simlint knows about; an allow naming anything else is malformed.
pub const RULES: &[&str] = &["nondet", "float-on-time", "panic-in-config", "calendar-discipline"];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Int,
    Float,
    Punct,
}

/// One lexed token with the 1-based source line it starts on.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

/// A parsed `// simlint: allow(<rule>, "<reason>")` comment.
#[derive(Debug, Clone)]
pub struct AllowSite {
    pub rule: String,
    pub reason: String,
    /// Line the allowance suppresses: the comment's own line when it
    /// trails code, the following line when it stands alone.
    pub target_line: u32,
    /// Line the comment itself is on (reported in the JSON).
    pub comment_line: u32,
}

/// Tokenized source plus the lint-control comments found along the way.
#[derive(Debug, Default)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub allows: Vec<AllowSite>,
    /// Lines whose comment says `simlint:` but does not parse as a
    /// well-formed allow (unknown rule, missing quoted reason, typo).
    pub malformed: Vec<u32>,
}

/// Lex `src`. Never fails: unrecognized bytes become inert punct tokens.
pub fn tokenize(src: &str) -> Lexed {
    let b = src.as_bytes();
    let n = b.len();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line: u32 = 1;
    let mut line_has_code = false;

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            line_has_code = false;
            i += 1;
            continue;
        }
        if c == b' ' || c == b'\t' || c == b'\r' {
            i += 1;
            continue;
        }
        // Line comment (incl. doc comments): capture simlint directives.
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let j = find_byte(b, i, b'\n');
            scan_comment(&src[i..j], line, line_has_code, &mut out);
            i = j;
            continue;
        }
        // Block comment; Rust block comments nest.
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                        line_has_code = false;
                    }
                    i += 1;
                }
            }
            continue;
        }
        line_has_code = true;
        // Raw string r"..." / r#"..."# (any hash depth). `r#ident` raw
        // identifiers fall through to the ident path below.
        if c == b'r' && i + 1 < n && (b[i + 1] == b'#' || b[i + 1] == b'"') {
            let mut j = i + 1;
            let mut hashes = 0usize;
            while j < n && b[j] == b'#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == b'"' {
                j += 1;
                'raw: while j < n {
                    if b[j] == b'\n' {
                        line += 1;
                    }
                    if b[j] == b'"' {
                        let mut k = j + 1;
                        let mut seen = 0usize;
                        while k < n && b[k] == b'#' && seen < hashes {
                            seen += 1;
                            k += 1;
                        }
                        if seen == hashes {
                            j = k;
                            break 'raw;
                        }
                    }
                    j += 1;
                }
                i = j;
                continue;
            }
        }
        // String literal (b"..." reaches here after the `b` ident).
        if c == b'"' {
            let mut j = i + 1;
            while j < n {
                if b[j] == b'\\' {
                    j += 2;
                    continue;
                }
                if b[j] == b'"' {
                    break;
                }
                if b[j] == b'\n' {
                    line += 1;
                }
                j += 1;
            }
            i = (j + 1).min(n);
            continue;
        }
        // Lifetime vs char literal.
        if c == b'\'' {
            let next_ident = i + 1 < n && (b[i + 1].is_ascii_alphabetic() || b[i + 1] == b'_');
            let closes = i + 2 < n && b[i + 2] == b'\'';
            if next_ident && !closes {
                let mut j = i + 1;
                while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                    j += 1;
                }
                i = j;
                continue;
            }
            let mut j = i + 1;
            if j < n && b[j] == b'\\' {
                j += 2;
                while j < n && b[j] != b'\'' {
                    j += 1;
                }
            } else {
                j += 1;
            }
            i = (j + 1).min(n);
            continue;
        }
        // Identifier / keyword.
        if c.is_ascii_alphabetic() || c == b'_' {
            let mut j = i;
            while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
                j += 1;
            }
            out.toks.push(tok(TokKind::Ident, &src[i..j], line));
            i = j;
            continue;
        }
        // Number literal.
        if c.is_ascii_digit() {
            let (j, is_float) = lex_number(b, i);
            let kind = if is_float { TokKind::Float } else { TokKind::Int };
            out.toks.push(tok(kind, &src[i..j], line));
            i = j;
            continue;
        }
        // Punctuation: join the two-char operators the rules care about.
        if c.is_ascii() {
            const TWO: &[&str] = &[
                "::", "==", "=>", "->", "!=", "<=", ">=", "+=", "-=", "*=", "/=", "|=", "&=", "..",
            ];
            let mut matched = false;
            for t in TWO {
                if src[i..].starts_with(t) {
                    out.toks.push(tok(TokKind::Punct, t, line));
                    i += 2;
                    matched = true;
                    break;
                }
            }
            if !matched {
                out.toks.push(tok(TokKind::Punct, &src[i..i + 1], line));
                i += 1;
            }
            continue;
        }
        // Non-ASCII outside comments/strings: skip the byte (no token).
        i += 1;
    }
    out
}

fn tok(kind: TokKind, text: &str, line: u32) -> Tok {
    Tok {
        kind,
        text: text.to_string(),
        line,
    }
}

fn find_byte(b: &[u8], from: usize, needle: u8) -> usize {
    let mut j = from;
    while j < b.len() && b[j] != needle {
        j += 1;
    }
    j
}

/// Consume a number starting at `i` (ascii digit). Returns (end, is_float).
fn lex_number(b: &[u8], i: usize) -> (usize, bool) {
    let n = b.len();
    let mut j = i;
    let mut is_float = false;
    if b[i] == b'0' && i + 1 < n && matches!(b[i + 1], b'x' | b'o' | b'b') {
        j = i + 2;
        while j < n && (b[j].is_ascii_alphanumeric() || b[j] == b'_') {
            j += 1;
        }
        return (j, false);
    }
    while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
        j += 1;
    }
    // Fractional part: `1.5` yes; `0..x` and `v.0` and `1.method()` no.
    if j < n && b[j] == b'.' && j + 1 < n && b[j + 1].is_ascii_digit() {
        is_float = true;
        j += 1;
        while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
            j += 1;
        }
    } else if j < n && b[j] == b'.' {
        // Trailing-dot float `1.` — but not a range `0..4`, a method call
        // `1.min(x)`, or a field access.
        let joins = match b.get(j + 1) {
            Some(&c) => c.is_ascii_alphanumeric() || c == b'_' || c == b'.',
            None => false,
        };
        if !joins {
            is_float = true;
            j += 1;
        }
    }
    // Exponent.
    if j < n && (b[j] == b'e' || b[j] == b'E') {
        let mut k = j + 1;
        if k < n && (b[k] == b'+' || b[k] == b'-') {
            k += 1;
        }
        if k < n && b[k].is_ascii_digit() {
            is_float = true;
            j = k;
            while j < n && (b[j].is_ascii_digit() || b[j] == b'_') {
                j += 1;
            }
        }
    }
    // Type suffix.
    let rest = &b[j..];
    if rest.starts_with(b"f64") || rest.starts_with(b"f32") {
        return (j + 3, true);
    }
    const INT_SUFFIXES: &[&str] = &[
        "usize", "isize", "u128", "i128", "u64", "i64", "u32", "i32", "u16", "i16", "u8", "i8",
    ];
    for s in INT_SUFFIXES {
        if rest.starts_with(s.as_bytes()) {
            return (j + s.len(), is_float);
        }
    }
    (j, is_float)
}

/// Parse one `//` comment for simlint directives.
fn scan_comment(comment: &str, line: u32, line_has_code: bool, out: &mut Lexed) {
    let Some(idx) = comment.find("simlint:") else {
        return;
    };
    let rest = comment[idx + "simlint:".len()..].trim_start();
    match parse_allow(rest) {
        Some((rule, reason)) if RULES.contains(&rule.as_str()) => {
            out.allows.push(AllowSite {
                rule,
                reason,
                target_line: if line_has_code { line } else { line + 1 },
                comment_line: line,
            });
        }
        _ => out.malformed.push(line),
    }
}

/// Parse `allow(<rule>, "<reason>")`; `None` on any shape mismatch.
fn parse_allow(s: &str) -> Option<(String, String)> {
    let s = s.strip_prefix("allow(")?;
    let comma = s.find(',')?;
    let rule = s[..comma].trim().to_string();
    let s = s[comma + 1..].trim_start();
    let s = s.strip_prefix('"')?;
    let endq = s.find('"')?;
    let reason = s[..endq].to_string();
    let s = s[endq + 1..].trim_start();
    if !s.starts_with(')') || rule.is_empty() || reason.is_empty() {
        return None;
    }
    Some((rule, reason))
}

/// Drop `#[cfg(test)]`-gated items and `#[test]` functions (with any
/// stacked attributes) from the token stream: simlint rules only apply to
/// shipping code, and the goldens/oracles may legitimately use wall
/// clocks, floats and hash iteration.
pub fn strip_test_regions(toks: Vec<Tok>) -> Vec<Tok> {
    let n = toks.len();
    let mut out = Vec::with_capacity(n);
    let mut i = 0usize;
    while i < n {
        match match_attr(&toks, i) {
            Some((end, true)) => {
                let mut j = end;
                while let Some((e2, _)) = match_attr(&toks, j) {
                    j = e2;
                }
                // Skip the gated item: to a top-level `;` (declarations)
                // or past the matching close of its first brace block.
                let mut depth = 0i32;
                while j < n {
                    match toks[j].text.as_str() {
                        ";" if depth == 0 => {
                            j += 1;
                            break;
                        }
                        "{" => depth += 1,
                        "}" => {
                            depth -= 1;
                            if depth == 0 {
                                j += 1;
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                i = j;
            }
            Some((end, false)) => {
                out.extend_from_slice(&toks[i..end]);
                i = end;
            }
            None => {
                out.push(toks[i].clone());
                i += 1;
            }
        }
    }
    out
}

/// If an outer attribute `#[...]` starts at `i`, return (end index, whether
/// it is `#[test]` or `#[cfg(test)]`).
fn match_attr(toks: &[Tok], i: usize) -> Option<(usize, bool)> {
    if toks.get(i)?.text != "#" || toks.get(i + 1)?.text != "[" {
        return None;
    }
    let mut depth = 0i32;
    let mut j = i + 1;
    let mut inner: Vec<&str> = Vec::new();
    loop {
        let t = toks.get(j)?;
        match t.text.as_str() {
            "[" => depth += 1,
            "]" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            other => inner.push(other),
        }
        j += 1;
    }
    let is_cfg_test = inner.len() >= 4
        && inner[0] == "cfg"
        && inner[1] == "("
        && inner[2] == "test"
        && inner[3] == ")";
    let is_test = inner.first() == Some(&"test") || is_cfg_test;
    Some((j + 1, is_test))
}

/// Line ranges (inclusive) of the bodies of `fn <name>` for each name in
/// `names`. Bodyless trait declarations (`fn validate(...);`) are skipped.
pub fn fn_body_ranges(toks: &[Tok], names: &[&str]) -> Vec<(u32, u32)> {
    let n = toks.len();
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < n {
        if toks[i].text == "fn" && i + 1 < n && names.contains(&toks[i + 1].text.as_str()) {
            let mut j = i + 2;
            while j < n && toks[j].text != "{" && toks[j].text != ";" {
                j += 1;
            }
            if j < n && toks[j].text == "{" {
                let start = toks[j].line;
                let mut depth = 0i32;
                while j < n {
                    match toks[j].text.as_str() {
                        "{" => depth += 1,
                        "}" => {
                            depth -= 1;
                            if depth == 0 {
                                break;
                            }
                        }
                        _ => {}
                    }
                    j += 1;
                }
                let end = if j < n { toks[j].line } else { start };
                ranges.push((start, end));
            }
            i = j;
        }
        i += 1;
    }
    ranges
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        tokenize(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn comments_and_strings_are_stripped() {
        let toks = texts("let x = \"now()\"; // Instant::now()\n/* HashMap */ let y = 1;");
        assert_eq!(toks, vec!["let", "x", "=", ";", "let", "y", "=", "1", ";"]);
    }

    #[test]
    fn nested_block_comments_close_correctly() {
        let toks = texts("/* a /* b */ c */ fn f() {}");
        assert_eq!(toks, vec!["fn", "f", "(", ")", "{", "}"]);
    }

    #[test]
    fn raw_strings_with_hashes_are_skipped() {
        let toks = texts("let s = r#\"quote \" inside\"#; done");
        assert_eq!(toks, vec!["let", "s", "=", ";", "done"]);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let toks = texts("fn f<'a>(x: &'a str, c: char) { let y = 'z'; let nl = '\\n'; }");
        assert!(toks.contains(&"str".to_string()));
        assert!(toks.contains(&"nl".to_string()));
        // Char literal contents never become tokens.
        assert!(!toks.contains(&"z".to_string()));
    }

    #[test]
    fn float_vs_int_classification() {
        let lexed = tokenize("a(1.5, 1e3, 2, 0x1F, 0..4, v.0, 50_000.0, 3f64, 9u32)");
        let floats: Vec<&str> = lexed
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Float)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(floats, vec!["1.5", "1e3", "50_000.0", "3f64"]);
        let ints: Vec<&str> = lexed
            .toks
            .iter()
            .filter(|t| t.kind == TokKind::Int)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(ints, vec!["2", "0x1F", "0", "4", "0", "9u32"]);
    }

    #[test]
    fn allow_comments_parse_with_target_lines() {
        let src = concat!(
            "// simlint: allow(nondet, \"standalone\")\n",
            "let a = 1;\n",
            "let b = 2; // simlint: allow(float-on-time, \"trailing\")\n",
        );
        let lexed = tokenize(src);
        assert_eq!(lexed.allows.len(), 2);
        assert_eq!(lexed.allows[0].rule, "nondet");
        assert_eq!(lexed.allows[0].target_line, 2);
        assert_eq!(lexed.allows[1].rule, "float-on-time");
        assert_eq!(lexed.allows[1].target_line, 3);
        assert!(lexed.malformed.is_empty());
    }

    #[test]
    fn malformed_allows_are_reported() {
        let src = concat!(
            "// simlint: allow(bogus-rule, \"x\")\n",
            "// simlint: allow(nondet)\n",
            "// simlint: typo\n",
        );
        let lexed = tokenize(src);
        assert!(lexed.allows.is_empty());
        assert_eq!(lexed.malformed, vec![1, 2, 3]);
    }

    #[test]
    fn cfg_test_items_are_stripped() {
        let src = concat!(
            "fn keep() {}\n",
            "#[cfg(test)]\n",
            "mod tests {\n    fn drop_me() {}\n}\n",
            "#[cfg(test)]\n",
            "#[allow(dead_code)]\n",
            "mod more {\n    fn also() {}\n}\n",
            "fn keep2() {}\n",
        );
        let kept = strip_test_regions(tokenize(src).toks);
        let names: Vec<&str> = kept.iter().map(|t| t.text.as_str()).collect();
        assert!(names.contains(&"keep"));
        assert!(names.contains(&"keep2"));
        assert!(!names.contains(&"drop_me"));
        assert!(!names.contains(&"also"));
    }

    #[test]
    fn test_attr_fns_are_stripped_and_other_attrs_kept() {
        let src = "#[derive(Debug)]\nstruct S;\n#[test]\nfn t() { let x = 1; }\nfn k() {}\n";
        let kept = strip_test_regions(tokenize(src).toks);
        let names: Vec<&str> = kept.iter().map(|t| t.text.as_str()).collect();
        assert!(names.contains(&"S"));
        assert!(names.contains(&"k"));
        assert!(!names.contains(&"t"));
        assert!(names.contains(&"derive"));
    }

    #[test]
    fn fn_bodies_are_ranged_and_declarations_skipped() {
        let src = concat!(
            "trait T {\n    fn validate(&self) -> bool;\n}\n",
            "fn validate() {\n    let x = 1;\n}\n",
        );
        let lexed = tokenize(src);
        let ranges = fn_body_ranges(&lexed.toks, &["validate"]);
        assert_eq!(ranges, vec![(4, 6)]);
    }
}
