//! The four simlint rules (DESIGN.md §14), run over the token stream from
//! [`crate::scan`].
//!
//! * `nondet` (R1) — no wall clocks, sleeps, or hash-order iteration
//!   anywhere in `rust/src`.
//! * `float-on-time` (R2) — integer-picosecond discipline in the hot
//!   timing modules: no float casts/literals touching time-typed values.
//! * `panic-in-config` (R3) — config-load paths return errors, never
//!   panic.
//! * `calendar-discipline` (R4) — event times are owned by `sim/`; no
//!   direct calendar types, event-time mutation, or `EventKey`
//!   construction outside it.

use crate::scan::{self, AllowSite, Tok, TokKind};

/// Hash-collection methods whose visit order is nondeterministic.
const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "values",
    "values_mut",
    "drain",
    "retain",
];

/// R2 applies to every file under these prefixes...
const R2_SCOPE_PREFIXES: &[&str] = &["sim/"];
/// ...plus these specific hot-path files (report/energy/analytic exempt).
const R2_SCOPE_FILES: &[&str] = &[
    "iface/bus.rs",
    "controller/way.rs",
    "controller/channel.rs",
    "controller/sched.rs",
    "coordinator/ssd.rs",
];

/// R3 applies inside these functions everywhere (plus all of `config/`).
const R3_FNS: &[&str] = &["validate", "from_toml"];

/// One rule hit, after test-region stripping but before allows are applied.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    pub rule: &'static str,
    pub line: u32,
    pub msg: String,
}

/// Lint result for a single file.
#[derive(Debug, Default)]
pub struct FileLint {
    /// Violations not suppressed by a matching allow, sorted by
    /// (line, rule, message).
    pub violations: Vec<Violation>,
    /// Every well-formed allow comment in the file (used or not — the
    /// report pins the total so silent allow growth is visible in review).
    pub allows: Vec<AllowSite>,
    /// Lines with a `simlint:` comment that does not parse as an allow.
    pub malformed: Vec<u32>,
}

/// Lint one file. `rel` is the path relative to the lint root
/// (e.g. `sim/queue.rs`) — rules R2-R4 are scoped by it.
pub fn lint_source(rel: &str, src: &str) -> FileLint {
    let lexed = scan::tokenize(src);
    let toks = scan::strip_test_regions(lexed.toks);
    let mut v: Vec<Violation> = Vec::new();

    rule_nondet(&toks, &mut v);
    rule_float_on_time(rel, &toks, &mut v);
    rule_panic_in_config(rel, &toks, &mut v);
    rule_calendar_discipline(rel, &toks, &mut v);

    // Apply allows: an allow suppresses every hit of its rule on its
    // target line (the annotated line, or the next line for a standalone
    // comment).
    v.retain(|viol| {
        !lexed
            .allows
            .iter()
            .any(|a| a.rule == viol.rule && a.target_line == viol.line)
    });
    v.sort_by(|a, b| (a.line, a.rule, &a.msg).cmp(&(b.line, b.rule, &b.msg)));

    FileLint {
        violations: v,
        allows: lexed.allows,
        malformed: lexed.malformed,
    }
}

fn push(v: &mut Vec<Violation>, rule: &'static str, line: u32, msg: String) {
    v.push(Violation { rule, line, msg });
}

/// R1: wall clocks, sleeps, and hash-order iteration.
fn rule_nondet(toks: &[Tok], v: &mut Vec<Violation>) {
    for k in 0..toks.len().saturating_sub(2) {
        let (a, b, c) = (&toks[k], &toks[k + 1], &toks[k + 2]);
        if (a.text == "Instant" || a.text == "SystemTime") && b.text == "::" && c.text == "now" {
            let msg = format!("wall-clock `{}::now` in simulator source", a.text);
            push(v, "nondet", a.line, msg);
        }
        if a.text == "thread" && b.text == "::" && c.text == "sleep" {
            push(v, "nondet", a.line, "`thread::sleep` in simulator source".to_string());
        }
    }

    let hnames = hash_names(toks);
    for k in 0..toks.len() {
        let t = &toks[k];
        if t.kind != TokKind::Ident || !hnames.contains(&t.text) {
            continue;
        }
        // `name.iter()` / `.keys()` / ... method-call iteration.
        if k + 2 < toks.len()
            && toks[k + 1].text == "."
            && ITER_METHODS.contains(&toks[k + 2].text.as_str())
        {
            let msg = format!(
                "iteration over hash collection `{}.{}()` (order is nondeterministic)",
                t.text,
                toks[k + 2].text
            );
            push(v, "nondet", t.line, msg);
        }
        // `for pat in [&][mut][self.]name {` — chain back to the `in`.
        let next_is_body = match toks.get(k + 1) {
            Some(nx) => nx.text == "{",
            None => true,
        };
        if next_is_body {
            let mut j = k;
            let mut steps = 0;
            let mut found_in = false;
            while j > 0 && steps < 8 {
                let prev = &toks[j - 1];
                if prev.text == "in" {
                    found_in = true;
                    break;
                }
                let chains = prev.kind == TokKind::Ident
                    || matches!(prev.text.as_str(), "&" | "mut" | ".");
                if !chains {
                    break;
                }
                j -= 1;
                steps += 1;
            }
            if found_in {
                let msg = format!(
                    "for-loop over hash collection `{}` (order is nondeterministic)",
                    t.text
                );
                push(v, "nondet", t.line, msg);
            }
        }
    }
}

/// Names bound to `HashMap`/`HashSet` in this file: struct fields and
/// typed bindings (`name: HashMap<...>`) and `let name = HashMap::...`.
/// Keyed lookup on these stays legal; only *iteration* is flagged.
fn hash_names(toks: &[Tok]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    for k in 0..toks.len() {
        if toks[k].text != "HashMap" && toks[k].text != "HashSet" {
            continue;
        }
        // `name : [std :: collections ::][&][mut] HashMap`
        let mut j = k;
        while j > 0
            && matches!(toks[j - 1].text.as_str(), "std" | "collections" | "::" | "&" | "mut")
        {
            j -= 1;
        }
        if j >= 2 && toks[j - 1].text == ":" && toks[j - 2].kind == TokKind::Ident {
            note(&mut names, &toks[j - 2].text);
            continue;
        }
        // `let name = HashMap::new()` / `= HashMap::with_capacity(..)`
        let mut j = k;
        let mut back = 0;
        while j > 0 && back < 8 {
            let t = toks[j - 1].text.as_str();
            if t == "=" {
                if j >= 2 && toks[j - 2].kind == TokKind::Ident {
                    note(&mut names, &toks[j - 2].text);
                }
                break;
            }
            if matches!(t, ";" | "{" | "}") {
                break;
            }
            j -= 1;
            back += 1;
        }
    }
    names
}

fn note(names: &mut Vec<String>, name: &str) {
    if !names.iter().any(|n| n == name) {
        names.push(name.to_string());
    }
}

/// True for identifiers that mark a time-typed value in the scoped
/// modules: the `Ps` newtype, `*_ps` fields, `t_*` locals, and the
/// scheduler vocabulary.
fn is_time_marker(t: &str) -> bool {
    matches!(t, "ps" | "now" | "at" | "horizon" | "lookahead" | "deadline" | "Ps")
        || t.ends_with("_ps")
        || t.starts_with("t_")
}

/// R2: float casts/literals on lines that touch a time-typed value, in the
/// integer-picosecond hot paths. The sanctioned boundary helpers
/// (`as_ns_f64` etc.) lex as single identifiers and pass untouched.
fn rule_float_on_time(rel: &str, toks: &[Tok], v: &mut Vec<Violation>) {
    let in_scope = R2_SCOPE_FILES.contains(&rel)
        || R2_SCOPE_PREFIXES.iter().any(|p| rel.starts_with(p));
    if !in_scope {
        return;
    }
    let mut i = 0;
    while i < toks.len() {
        let line = toks[i].line;
        let mut j = i;
        while j < toks.len() && toks[j].line == line {
            j += 1;
        }
        let lt = &toks[i..j];
        i = j;

        let has_marker = lt
            .iter()
            .any(|t| t.kind == TokKind::Ident && is_time_marker(&t.text));
        if !has_marker {
            continue;
        }
        let has_cast = lt.windows(2).any(|w| {
            w[0].text == "as" && (w[1].text == "f64" || w[1].text == "f32")
        });
        let has_float = lt.iter().any(|t| t.kind == TokKind::Float);
        if has_cast {
            push(v, "float-on-time", line, "float cast on a time-typed expression".to_string());
        } else if has_float {
            push(
                v,
                "float-on-time",
                line,
                "float literal in arithmetic with a time-typed value".to_string(),
            );
        }
    }
}

/// R3: `.unwrap()`/`.expect()`/`panic!` in config-load paths — all of
/// `config/`, plus `validate`/`from_toml` bodies anywhere.
fn rule_panic_in_config(rel: &str, toks: &[Tok], v: &mut Vec<Violation>) {
    let r3_all = rel.starts_with("config/");
    let ranges = scan::fn_body_ranges(toks, R3_FNS);
    let in_r3 = |line: u32| r3_all || ranges.iter().any(|&(lo, hi)| (lo..=hi).contains(&line));
    for k in 0..toks.len().saturating_sub(1) {
        let (t, nx) = (&toks[k], &toks[k + 1]);
        if !in_r3(t.line) {
            continue;
        }
        if (t.text == "unwrap" || t.text == "expect") && nx.text == "(" {
            let msg = format!("`.{}()` in a config-load path (return an error instead)", t.text);
            push(v, "panic-in-config", t.line, msg);
        }
        if t.text == "panic" && nx.text == "!" {
            push(
                v,
                "panic-in-config",
                t.line,
                "`panic!` in a config-load path (return an error instead)".to_string(),
            );
        }
    }
}

/// R4: outside `sim/`, no direct calendar types, no assignment to an
/// event's `.at`/`.now` time field, and no `EventKey` struct-literal
/// construction — scheduling goes through `Scheduler`/`Emit::send_at`,
/// and hub/shard keys are minted by the engine (`HubEmit::send_at`).
/// Reading key fields and matching on keys stays legal.
fn rule_calendar_discipline(rel: &str, toks: &[Tok], v: &mut Vec<Violation>) {
    if rel.starts_with("sim/") {
        return;
    }
    for k in 0..toks.len() {
        let t = &toks[k];
        if t.text == "EventQueue" || t.text == "HeapEventQueue" {
            let msg = format!(
                "direct use of `{}` outside sim/ (schedule via Scheduler/Emit)",
                t.text
            );
            push(v, "calendar-discipline", t.line, msg);
        }
        // `EventKey { ... }` literal (type position `-> EventKey {` is the
        // function body's brace, not a literal, and stays legal).
        if t.text == "EventKey"
            && toks.get(k + 1).is_some_and(|nx| nx.text == "{")
            && (k == 0 || toks[k - 1].text != "->")
        {
            push(
                v,
                "calendar-discipline",
                t.line,
                "struct-literal construction of `EventKey` outside sim/ (keys are minted by the engine)"
                    .to_string(),
            );
        }
        if t.text == "."
            && k + 2 < toks.len()
            && matches!(toks[k + 1].text.as_str(), "at" | "now")
            && toks[k + 2].text == "="
        {
            let msg = format!("direct mutation of event time field `.{}`", toks[k + 1].text);
            push(v, "calendar-discipline", toks[k + 1].line, msg);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_calls_are_flagged_and_allowed() {
        let src = concat!(
            "fn f() {\n",
            "    let t0 = Instant::now();\n",
            "    // simlint: allow(nondet, \"timed harness\")\n",
            "    let t1 = std::time::Instant::now();\n",
            "}\n",
        );
        let fl = lint_source("bench.rs", src);
        assert_eq!(fl.violations.len(), 1);
        assert_eq!(fl.violations[0].line, 2);
        assert_eq!(fl.allows.len(), 1);
    }

    #[test]
    fn hash_iteration_is_flagged_but_keyed_lookup_is_not() {
        let src = concat!(
            "struct S { m: HashMap<u32, u32> }\n",
            "fn f(s: &S) -> Option<&u32> { s.m.get(&3) }\n",
            "fn g(s: &S) -> usize { s.m.iter().count() }\n",
            "fn h(s: &S) {\n",
            "    for x in &s.m {\n",
            "        let _ = x;\n",
            "    }\n",
            "}\n",
        );
        let fl = lint_source("controller/cache.rs", src);
        let lines: Vec<u32> = fl.violations.iter().map(|v| v.line).collect();
        assert_eq!(lines, vec![3, 5]);
        assert!(fl.violations[0].msg.contains("m.iter()"));
        assert!(fl.violations[1].msg.contains("for-loop"));
    }

    #[test]
    fn self_field_for_loop_is_caught() {
        let src = concat!(
            "struct S { entries: HashMap<u64, u64> }\n",
            "impl S {\n",
            "    fn scan(&self) {\n",
            "        for e in &self.entries {\n",
            "            let _ = e;\n",
            "        }\n",
            "    }\n",
            "}\n",
        );
        let fl = lint_source("controller/cache.rs", src);
        assert_eq!(fl.violations.len(), 1);
        assert_eq!(fl.violations[0].line, 4);
    }

    #[test]
    fn float_on_time_scoping() {
        let src = "fn f(t_busy: u64) -> f64 { t_busy as f64 }\n";
        assert_eq!(lint_source("sim/engine.rs", src).violations.len(), 1);
        assert!(lint_source("report/mod.rs", src).violations.is_empty());
        // Sanctioned boundary helper lexes as one identifier: clean.
        let ok = "fn g(p: Ps) -> u64 { p.checked_ps() }\n";
        assert!(lint_source("sim/engine.rs", ok).violations.is_empty());
    }

    #[test]
    fn panic_in_config_scoping() {
        let src = concat!(
            "fn load(s: &str) -> u32 { s.parse().unwrap() }\n",
            "fn validate(x: u32) -> u32 {\n",
            "    assert_ne!(x, 0);\n",
            "    x.checked_mul(2).expect(\"overflow\")\n",
            "}\n",
        );
        // In config/, both fns are in scope.
        assert_eq!(lint_source("config/mod.rs", src).violations.len(), 2);
        // Elsewhere, only the `validate` body is.
        let fl = lint_source("report/mod.rs", src);
        assert_eq!(fl.violations.len(), 1);
        assert_eq!(fl.violations[0].line, 4);
    }

    #[test]
    fn calendar_discipline_outside_sim_only() {
        let src = concat!(
            "fn f(q: &mut EventQueue, ev: &mut Ev) {\n",
            "    ev.at = 5;\n",
            "}\n",
        );
        let fl = lint_source("controller/sched.rs", src);
        assert_eq!(fl.violations.len(), 2);
        assert!(lint_source("sim/queue.rs", src).violations.is_empty());
    }

    #[test]
    fn test_modules_are_exempt() {
        let src = concat!(
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    fn t() {\n",
            "        let t0 = Instant::now();\n",
            "        let _ = t0;\n",
            "    }\n",
            "}\n",
        );
        assert!(lint_source("sim/engine.rs", src).violations.is_empty());
    }
}
