//! CLI entry point: `cargo run -p xtask -- lint [--root <dir>] [--json <path>]`.
//!
//! Exit codes: 0 = clean, 1 = violations or malformed allow comments,
//! 2 = usage or I/O error. See DESIGN.md §14 for the rules.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(&args[1..]),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint [--root <dir>] [--json <path>]");
            ExitCode::from(2)
        }
    }
}

fn run_lint(args: &[String]) -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut json_out: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => root = it.next().map(PathBuf::from),
            "--json" => json_out = it.next().map(PathBuf::from),
            other => {
                eprintln!("unknown argument `{other}`");
                return ExitCode::from(2);
            }
        }
    }
    let root = root.unwrap_or_else(default_root);
    if !root.is_dir() {
        eprintln!("lint root `{}` is not a directory", root.display());
        return ExitCode::from(2);
    }

    let rep = match xtask::lint_tree(&root) {
        Ok(rep) => rep,
        Err(e) => {
            eprintln!("simlint: walk failed: {e}");
            return ExitCode::from(2);
        }
    };

    for v in &rep.violations {
        println!("{}:{}: [{}] {}", v.file, v.line, v.rule, v.msg);
    }
    for (file, line) in &rep.malformed {
        println!("{file}:{line}: [malformed] unparseable `simlint:` comment");
    }
    for a in &rep.allows {
        println!("{}:{}: allow({}) — {}", a.file, a.line, a.rule, a.reason);
    }
    println!(
        "simlint: {} files, {} violations, {} allows, {} malformed",
        rep.files_scanned,
        rep.violations.len(),
        rep.allows.len(),
        rep.malformed.len()
    );

    if let Some(path) = json_out {
        let text = rep.to_json();
        if let Err(e) = xtask::report::validate_report_json(&text) {
            eprintln!("simlint: report failed self-validation: {e}");
            return ExitCode::from(2);
        }
        if let Err(e) = std::fs::write(&path, &text) {
            eprintln!("simlint: cannot write `{}`: {e}", path.display());
            return ExitCode::from(2);
        }
        println!("simlint: report written to {}", path.display());
    }

    if rep.is_clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

/// `rust/src` relative to the workspace: the current directory when run
/// from the workspace root (the `cargo run -p xtask` case), else resolved
/// from this crate's manifest.
fn default_root() -> PathBuf {
    let cwd = PathBuf::from("rust/src");
    if cwd.is_dir() {
        cwd
    } else {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/../rust/src"))
    }
}
