//! In-workspace dev tasks for the ddrnand workspace.
//!
//! The only task so far is **simlint** (`cargo run -p xtask -- lint`): a
//! token-level static-analysis pass over `rust/src/**` that enforces the
//! determinism and timing invariants written down in DESIGN.md §14. It is
//! deliberately dependency-free — a hand-rolled scanner in the same
//! spirit as `ddrnand::bench::json` — so it builds offline and runs as a
//! blocking CI job.

pub mod report;
pub mod rules;
pub mod scan;

use std::io;
use std::path::{Path, PathBuf};

use report::{Report, ReportAllow, ReportViolation};

/// Lint every `.rs` file under `root` (sorted walk, so diagnostics and
/// the JSON report are deterministic).
pub fn lint_tree(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();

    let mut rep = Report {
        root: root.display().to_string(),
        ..Report::default()
    };
    for path in &files {
        let src = std::fs::read_to_string(path)?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        let fl = rules::lint_source(&rel, &src);
        rep.files_scanned += 1;
        for v in fl.violations {
            rep.violations.push(ReportViolation {
                file: rel.clone(),
                line: v.line,
                rule: v.rule,
                msg: v.msg,
            });
        }
        for a in fl.allows {
            rep.allows.push(ReportAllow {
                file: rel.clone(),
                line: a.comment_line,
                rule: a.rule,
                reason: a.reason,
            });
        }
        for line in fl.malformed {
            rep.malformed.push((rel.clone(), line));
        }
    }
    Ok(rep)
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .map(|e| e.map(|e| e.path()))
        .collect::<io::Result<_>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}
