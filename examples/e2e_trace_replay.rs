//! END-TO-END driver: proves all layers compose on a real small workload.
//!
//! Pipeline exercised:
//!   1. generate an MMC-style sequential trace (64 KiB chunks, the paper's
//!      workload [30]) + a mixed read/write trace, write them to disk;
//!   2. parse them back and replay through the FULL system — SATA link →
//!      DRAM cache → FTL (page-map, GC-capable) → channel/way schedulers →
//!      interface bus models → NAND chips — for all three interfaces;
//!   3. load the AOT JAX/Pallas artifact via PJRT and compare the analytic
//!      prediction against the DES measurement;
//!   4. report the paper's headline metric: PROPOSED/CONV speedup.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_trace_replay
//! ```
//!
//! The output of this run is recorded in EXPERIMENTS.md §E2E.

use ddrnand::analytic;
use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::run_trace;
use ddrnand::host::trace::{RequestKind, Trace, TraceGen};
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;
use ddrnand::report::Table;
use ddrnand::runtime::Runtime;

fn main() {
    // --- 1. generate + persist traces (512 x 64 KiB = 32 MiB each) ---
    let gen = TraceGen::default();
    let dir = std::env::temp_dir().join("ddrnand_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let mut paths = Vec::new();
    for (name, trace) in [
        ("seq_write.trace", gen.sequential(RequestKind::Write, 512)),
        ("seq_read.trace", gen.sequential(RequestKind::Read, 512)),
        ("mixed.trace", gen.mixed_sequential(512, 0.5, 42)),
    ] {
        let path = dir.join(name);
        std::fs::write(&path, trace.to_text()).unwrap();
        paths.push(path);
    }
    println!("wrote 3 traces (32 MiB payload each) to {}\n", dir.display());

    // --- 2. replay through the full system ---
    let runtime = Runtime::artifacts_present(&Runtime::default_dir())
        .then(|| Runtime::load(&Runtime::default_dir()).expect("artifact load"));
    if runtime.is_some() {
        println!("AOT artifacts loaded via PJRT (analytic column below runs through HLO)\n");
    }

    let mut headline: Vec<(String, f64)> = Vec::new();
    for cell in [CellType::Slc, CellType::Mlc] {
        let mut t = Table::new(vec![
            "trace", "iface", "DES MB/s", "analytic MB/s", "gap", "mean lat (us)", "nJ/B",
        ]);
        let mut by_trace: std::collections::BTreeMap<String, Vec<f64>> = Default::default();
        for path in &paths {
            let text = std::fs::read_to_string(path).unwrap();
            let trace = Trace::from_text(&text).unwrap();
            let tname = path.file_name().unwrap().to_string_lossy().to_string();
            for iface in InterfaceKind::ALL {
                let cfg = SsdConfig {
                    iface,
                    cell,
                    channels: 1,
                    ways: 8,
                    blocks_per_chip: 512,
                    ..SsdConfig::default()
                };
                let rep = run_trace(&cfg, &trace);
                // Analytic prediction for the dominant mode of this trace —
                // through the AOT artifact when present.
                let mode = if tname.contains("read") {
                    RequestKind::Read
                } else {
                    RequestKind::Write
                };
                let ana = match &runtime {
                    Some(rt) => {
                        let p = analytic::DesignPoint::from_config(&cfg);
                        let o = rt.perf_batch(&[p]).expect("perf batch")[0];
                        if mode == RequestKind::Read {
                            o[0]
                        } else {
                            o[1]
                        }
                    }
                    None => analytic::evaluate(&cfg, mode).0,
                };
                let gap = if tname.contains("mixed") {
                    "-".to_string() // analytic models single-mode workloads
                } else {
                    format!("{:+.1}%", (rep.bandwidth_mbps - ana) / ana * 100.0)
                };
                t.row(vec![
                    tname.clone(),
                    iface.name().to_string(),
                    format!("{:.2}", rep.bandwidth_mbps),
                    format!("{ana:.2}"),
                    gap,
                    format!("{:.0}", rep.latency_mean_us),
                    format!("{:.3}", rep.energy_nj_per_byte),
                ]);
                by_trace.entry(tname.clone()).or_default().push(rep.bandwidth_mbps);
            }
        }
        println!("{cell}, 1ch x 8way, full-system replay:\n{}", t.render());
        for (tname, bws) in by_trace {
            // bws ordered CONV, SYNC_ONLY, PROPOSED per trace.
            headline.push((format!("{cell} {tname}"), bws[2] / bws[0]));
        }
    }

    // --- 4. headline ---
    println!("headline — PROPOSED/CONV speedup at 8-way (paper §6: read 1.65–2.76x, write 1.09–2.45x):");
    for (name, ratio) in &headline {
        println!("  {name:<26} {ratio:.2}x");
    }
    let ok = headline.iter().all(|(_, r)| *r > 1.05);
    println!(
        "\nE2E {}: all layers composed (trace I/O -> DES -> PJRT analytic), PROPOSED wins every workload",
        if ok { "PASS" } else { "FAIL" }
    );
    std::process::exit(if ok { 0 } else { 1 });
}
