//! Quickstart: simulate one SSD design and print its report.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::Campaign;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;
use ddrnand::report;

fn main() {
    // A 1-channel, 8-way SLC SSD with the paper's proposed DDR interface.
    let cfg = SsdConfig {
        iface: InterfaceKind::Proposed,
        cell: CellType::Slc,
        channels: 1,
        ways: 8,
        ..SsdConfig::default()
    };

    println!("quickstart: {:?} {} {}ch x {}way", cfg.iface, cfg.cell, cfg.channels, cfg.ways);
    println!(
        "interface operating point: {} MHz ({} data edges/clock)\n",
        cfg.params.operating_freq_mhz(cfg.iface),
        cfg.iface.beats_per_cycle(),
    );

    // The paper's workload: sequential 64 KiB requests.
    for mode in [RequestKind::Write, RequestKind::Read] {
        let rep = Campaign::new(cfg.clone(), mode, 200).run();
        println!("{}", report::summarize(&rep));
    }

    // Compare against the conventional interface in one line each.
    println!("\nvs CONV on the same hardware:");
    for mode in [RequestKind::Write, RequestKind::Read] {
        let conv = Campaign::new(
            SsdConfig {
                iface: InterfaceKind::Conv,
                ..cfg.clone()
            },
            mode,
            200,
        )
        .run();
        let prop = Campaign::new(cfg.clone(), mode, 200).run();
        println!(
            "  {:<5}: PROPOSED {:.2} MB/s vs CONV {:.2} MB/s -> {:.2}x",
            mode.name(),
            prop.bandwidth_mbps,
            conv.bandwidth_mbps,
            prop.bandwidth_mbps / conv.bandwidth_mbps
        );
    }
}
