//! Fig. 10 scenario: when does the faster interface also become the more
//! energy-efficient one?
//!
//! ```bash
//! cargo run --release --example energy_report
//! ```

use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::Campaign;
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::report::Table;

fn main() {
    let pool = ThreadPool::new(0);
    let ways = [1u16, 2, 4, 8, 16];
    for mode in [RequestKind::Write, RequestKind::Read] {
        let mut jobs = Vec::new();
        for &w in &ways {
            for iface in InterfaceKind::ALL {
                let cfg = SsdConfig {
                    iface,
                    ways: w,
                    blocks_per_chip: 512,
                    ..SsdConfig::default()
                };
                jobs.push(move || {
                    let rep = Campaign::new(cfg, mode, 300).run();
                    (w, iface, rep.bandwidth_mbps, rep.energy_nj_per_byte)
                });
            }
        }
        let results = pool.run_all(jobs);
        let mut t = Table::new(vec!["ways", "iface", "MB/s", "nJ/B", "cheapest?"]);
        for chunk in results.chunks(3) {
            let min_e = chunk
                .iter()
                .map(|r| r.3)
                .fold(f64::INFINITY, f64::min);
            for &(w, iface, bw, e) in chunk {
                t.row(vec![
                    w.to_string(),
                    iface.name().to_string(),
                    format!("{bw:.2}"),
                    format!("{e:.3}"),
                    if (e - min_e).abs() < 1e-9 { "<--".into() } else { String::new() },
                ]);
            }
        }
        println!("SLC {} energy (controller nJ per transferred byte):\n{}", mode.name(), t.render());
    }
    println!(
        "Observation (paper §5.3.3): the 83 MHz designs burn more power, so at low\n\
         interleaving CONV is cheaper per byte; once way interleaving lets PROPOSED's\n\
         bandwidth pull away, it becomes the cheapest — the paper's argument that\n\
         high-interleave SSDs should adopt the DDR interface for energy too."
    );
}
