//! Fig. 9 scenario: constant-capacity design exploration — channels are
//! expensive (pins + NAND_IF + ECC per channel), ways are cheap. Where
//! should a designer spend?
//!
//! ```bash
//! cargo run --release --example channel_striping
//! ```

use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::Campaign;
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;
use ddrnand::report::Table;

fn main() {
    let pool = ThreadPool::new(0);
    // 16 chips total, arranged three ways (the paper's Table 4 axis),
    // plus two extra arrangements for context.
    let configs = [(1u16, 16u16), (2, 8), (4, 4), (8, 2), (16, 1)];

    for cell in [CellType::Slc, CellType::Mlc] {
        for mode in [RequestKind::Write, RequestKind::Read] {
            let mut jobs = Vec::new();
            for &(ch, w) in &configs {
                for iface in [InterfaceKind::Conv, InterfaceKind::Proposed] {
                    let cfg = SsdConfig {
                        iface,
                        cell,
                        channels: ch,
                        ways: w,
                        blocks_per_chip: 256,
                        ..SsdConfig::default()
                    };
                    jobs.push(move || {
                        let rep = Campaign::new(cfg, mode, 300).run();
                        (ch, w, iface, rep.bandwidth_mbps, rep.sata_utilization)
                    });
                }
            }
            let results = pool.run_all(jobs);
            let mut t = Table::new(vec!["config", "iface", "MB/s", "SATA util"]);
            for (ch, w, iface, bw, su) in results {
                t.row(vec![
                    format!("{ch}ch x {w}way"),
                    iface.name().to_string(),
                    format!("{bw:.2}"),
                    format!("{:.0}%", su * 100.0),
                ]);
            }
            println!("{cell} {} (16 chips, constant capacity):\n{}", mode.name(), t.render());
        }
    }
    println!(
        "Observation (paper §5.3.2): in write mode, spending area on ways beats\n\
         channels when the budget is tight (t_PROG needs deep interleaving to hide);\n\
         in read mode channels pay off immediately — until SATA saturates."
    );
}
