//! Design-space exploration through the AOT-compiled JAX/Pallas analytic
//! model (PJRT), including the t_BYTE "extra metal layer" ablation (A2) and
//! the PVT Monte Carlo sensitivity analysis (A3) — then cross-validates the
//! winning design against the discrete-event simulator.
//!
//! ```bash
//! make artifacts && cargo run --release --example dse_explore
//! ```

use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::Campaign;
use ddrnand::dse::{evaluate, pareto_front, rank, Space};
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::IfaceParams;
use ddrnand::runtime::{iface_params_row, Runtime, MC_S};
use ddrnand::util::prng::Prng;

fn main() {
    let dir = Runtime::default_dir();
    let runtime = if Runtime::artifacts_present(&dir) {
        println!("loading AOT artifacts from {} ...", dir.display());
        let rt = Runtime::load(&dir).expect("artifact load");
        println!("PJRT compile: {:.1} ms (one-off; reused for every batch)\n", rt.compile_ms);
        Some(rt)
    } else {
        println!("artifacts missing — run `make artifacts` for the PJRT path; using native model\n");
        None
    };

    // A2: sweep t_BYTE to model the "extra metal layer" discussion (§5.1).
    let space = Space {
        t_byte_sweep: vec![12.0, 10.0, 8.0, 6.0, 4.0],
        ..Space::default()
    };
    let (cands, backend) = evaluate(&space, runtime.as_ref()).expect("evaluate");
    println!("evaluated {} candidates via {backend:?}", cands.len());

    let ranked = rank(cands);
    println!("\ntop designs by bandwidth-per-area merit:");
    for c in ranked.iter().take(8) {
        println!(
            "  {:<9} {} {}ch x {:>2}way t_BYTE={:>2}ns  read={:>7.2} write={:>6.2} MB/s  merit={:.2}",
            c.iface.name(),
            c.cell.name(),
            c.channels,
            c.ways,
            c.t_byte_ns.unwrap_or(12.0),
            c.read_bw,
            c.write_bw,
            c.merit()
        );
    }
    let front = pareto_front(&ranked);
    println!("\nPareto front: {} of {} designs", front.len(), ranked.len());

    // A3: PVT Monte Carlo through the mc artifact.
    if let Some(rt) = &runtime {
        let mut rng = Prng::new(0xA3);
        let z: Vec<f32> = (0..MC_S * 4).map(|_| rng.next_gaussian() as f32).collect();
        let corner = iface_params_row(&IfaceParams::default());
        println!("\nA3 — PVT violation probability vs clock margin (10%/5% chip/board sigma):");
        println!("  margin   CONV    SYNC_ONLY  PROPOSED");
        for margin in [1.0, 1.02, 1.05, 1.10, 1.20] {
            let p = rt
                .mc_batch(&[corner], &z, [0.10, 0.05, margin])
                .expect("mc")[0];
            println!("  {margin:<6}  {:.4}  {:.4}     {:.4}", p[0], p[1], p[2]);
        }
        println!("  (CONV's three varying paths need real margin; DVS designs barely care)");
    }

    // Cross-validate the best stock design (t_BYTE = 12) against the DES.
    let best = ranked
        .iter()
        .find(|c| c.t_byte_ns == Some(12.0))
        .expect("stock design in ranking");
    let cfg = SsdConfig {
        iface: best.iface,
        cell: best.cell,
        channels: best.channels,
        ways: best.ways,
        blocks_per_chip: 256,
        ..SsdConfig::default()
    };
    println!(
        "\ncross-validating winner ({} {} {}ch x {}way) against the DES:",
        best.iface.name(),
        best.cell.name(),
        best.channels,
        best.ways
    );
    for (mode, predicted) in [(RequestKind::Read, best.read_bw), (RequestKind::Write, best.write_bw)] {
        let des = Campaign::new(cfg.clone(), mode, 300).run().bandwidth_mbps;
        println!(
            "  {:<5}: analytic {predicted:.2} MB/s, DES {des:.2} MB/s ({:+.1}%)",
            mode.name(),
            (des - predicted) / predicted * 100.0
        );
    }
}
