//! Fig. 8 scenario: how way interleaving amplifies the DDR interface's
//! advantage (the paper's central interaction effect).
//!
//! ```bash
//! cargo run --release --example way_interleave_sweep
//! ```

use ddrnand::analytic;
use ddrnand::config::SsdConfig;
use ddrnand::coordinator::campaign::Campaign;
use ddrnand::coordinator::pool::ThreadPool;
use ddrnand::host::trace::RequestKind;
use ddrnand::iface::timing::InterfaceKind;
use ddrnand::nand::datasheet::CellType;
use ddrnand::report::Table;

fn main() {
    let pool = ThreadPool::new(0);
    let ways = [1u16, 2, 4, 8, 16];

    for mode in [RequestKind::Write, RequestKind::Read] {
        let mut jobs = Vec::new();
        for &w in &ways {
            for iface in InterfaceKind::ALL {
                let cfg = SsdConfig {
                    iface,
                    cell: CellType::Slc,
                    ways: w,
                    blocks_per_chip: 512,
                    ..SsdConfig::default()
                };
                jobs.push(move || {
                    let des = Campaign::new(cfg.clone(), mode, 300).run().bandwidth_mbps;
                    let ana = analytic::evaluate(&cfg, mode).0;
                    (w, iface, des, ana)
                });
            }
        }
        let results = pool.run_all(jobs);
        let mut t = Table::new(vec!["ways", "iface", "DES MB/s", "analytic MB/s", "gap"]);
        for (w, iface, des, ana) in results {
            t.row(vec![
                w.to_string(),
                iface.name().to_string(),
                format!("{des:.2}"),
                format!("{ana:.2}"),
                format!("{:+.1}%", (des - ana) / ana * 100.0),
            ]);
        }
        println!("SLC {} — DES vs analytic model:\n{}", mode.name(), t.render());
    }

    println!(
        "Observation (paper §5.3.1): CONV's read bandwidth saturates by 2-way while\n\
         PROPOSED keeps scaling to 4-way; in write mode PROPOSED sustains interleave\n\
         gains through 16-way because each page occupies the bus for half as long."
    );
}
